"""Clustered serving demo: the paper's task manager placing real requests.

Compares centralized (k=1), clustered (k=4) and fully-distributed (k=16)
scheduler configurations on placement balance + beacon traffic, injects a
worker-group failure, and drives real (reduced-model) decode steps for the
winning configuration.

    PYTHONPATH=src python examples/serve_clustered.py
"""
import numpy as np

from repro.configs import get_config, reduced_config
from repro.launch.serve import serve
from repro.serving.engine import FleetSim, Request


def control_plane_comparison(n_requests=256, groups_total=16):
    print("== control plane: k (clusters) sweep ==")
    rng = np.random.default_rng(0)
    for k in (1, 4, 16):
        gpc = groups_total // k
        fleet = FleetSim(k=k, groups_per_cluster=gpc, dn_th=4)
        for i in range(n_requests):
            fleet.submit(Request(sort_key=float(i), rid=i,
                                 prompt_len=int(rng.integers(16, 512)),
                                 max_new=32))
        print(f"  k={k:2d}: imbalance={fleet.imbalance():.3f} "
              f"beacons={fleet.beacons_tx:4d} "
              f"(messages per request: "
              f"{fleet.beacons_tx / n_requests:.2f})")


def failure_demo():
    print("== failure recovery ==")
    fleet = FleetSim(k=4, groups_per_cluster=4, dn_th=4)
    for i in range(64):
        fleet.submit(Request(sort_key=float(i), rid=i, max_new=16))
    orphans = fleet.kill(1, 2)
    print(f"  killed cluster1/group2: {orphans} requests re-placed")
    while fleet.active:
        fleet.tick()
    print(f"  completed {len(fleet.finished)}/64 (none lost)")


def main():
    control_plane_comparison()
    failure_demo()
    print("== data plane: real decode steps under the k=4 scheduler ==")
    cfg = reduced_config(get_config("olmo_1b"))
    serve(cfg, n_requests=32, clusters=4, groups_per_cluster=2, dn_th=4)


if __name__ == "__main__":
    main()
