"""End-to-end training driver: a ~100M-param OLMo-style LM for a few
hundred steps with fault-tolerant checkpointing.

    PYTHONPATH=src python examples/train_tiny_lm.py \\
        [--steps 300] [--params-m 100] [--crash-demo]

--crash-demo injects a failure mid-run and resumes from the latest
committed checkpoint, demonstrating the restart path end-to-end.
CPU throughput note: ~100M params needs a few seconds/step on this
container; use --params-m 25 for a fast pass.
"""
import argparse
import dataclasses
import tempfile

from repro.configs import RunConfig, get_config, reduced_config
from repro.launch.train import train


def sized_config(params_m: float):
    """Scale the OLMo family to roughly `params_m` million parameters."""
    base = get_config("olmo_1b")
    # tied embeddings: N ~= V*d + L*(4*d^2 + 3*d*dff) with dff=4d
    d = 256
    L = 4
    while True:
        n = 50304 * d + L * (4 * d * d + 3 * d * 4 * d)
        if n >= params_m * 1e6:
            break
        if L < d // 32:
            L += 2
        else:
            d += 64
    return dataclasses.replace(
        base, n_layers=L, d_model=d, n_heads=max(d // 64, 1),
        n_kv_heads=max(d // 64, 1), d_head=64, d_ff=4 * d), d, L


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--params-m", type=float, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--crash-demo", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg, d, L = sized_config(args.params_m)
    print(f"model: {cfg.param_count()/1e6:.0f}M params "
          f"(d={d}, L={L}, vocab={cfg.vocab_size})")
    run = RunConfig(param_dtype="float32", learning_rate=6e-4,
                    schedule="wsd", warmup_steps=max(args.steps // 20, 1),
                    total_steps=args.steps)
    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="tinylm_")

    if args.crash_demo:
        crash_at = args.steps // 2
        print(f"[demo] will crash at step {crash_at}, then resume")
        try:
            train(cfg, run, steps=args.steps, batch=args.batch, seq=args.seq,
                  ckpt_dir=ckpt, ckpt_every=max(args.steps // 10, 1),
                  fail_at=crash_at)
        except RuntimeError as e:
            print(f"[demo] crashed as planned: {e}")
        print("[demo] resuming from latest committed checkpoint...")
        train(cfg, run, steps=args.steps, batch=args.batch, seq=args.seq,
              ckpt_dir=ckpt, ckpt_every=max(args.steps // 10, 1), resume=True)
    else:
        train(cfg, run, steps=args.steps, batch=args.batch, seq=args.seq,
              ckpt_dir=ckpt, ckpt_every=max(args.steps // 10, 1))
    print(f"checkpoints in {ckpt}")


if __name__ == "__main__":
    main()
