"""Quickstart: build an architecture, run forward/loss/train-step/decode.

    PYTHONPATH=src python examples/quickstart.py [--arch glm4_9b]

Uses the reduced (CPU-sized) config of the chosen architecture; the full
published config is exercised by the 512-device dry-run
(`python -m repro.launch.dryrun --arch glm4_9b --shape train_4k`).
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import RunConfig, get_config, reduced_config
from repro.launch.steps import make_train_step
from repro.models import model as MDL
from repro.optim import optimizer as OPT


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4_9b")
    args = ap.parse_args()

    full = get_config(args.arch)
    cfg = reduced_config(full)
    print(f"arch={full.name}: {full.param_count()/1e9:.2f}B params "
          f"(reduced for CPU: {cfg.n_layers}L d={cfg.d_model})")

    key = jax.random.PRNGKey(0)
    params = MDL.init_model(key, cfg, jnp.float32)
    B, S = 2, 32
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    extra = {}
    if cfg.frontend == "vision":
        extra["patches"] = jnp.zeros((B, cfg.vision_tokens, cfg.d_model))
    if cfg.family == "encdec":
        extra["frames"] = jnp.zeros((B, cfg.enc_seq_len, cfg.d_model))

    logits, _ = MDL.forward(params, cfg, tokens, extra=extra, remat="none")
    print("forward:", logits.shape)

    run = RunConfig(param_dtype="float32", total_steps=10, warmup_steps=1)
    step = jax.jit(make_train_step(cfg, run))
    opt = OPT.init_opt_state(params, run)
    batch = {"tokens": tokens, "labels": tokens, **extra}
    for i in range(3):
        params, opt, metrics = step(params, opt, batch)
        print(f"train step {i}: loss={float(metrics['loss']):.4f}")

    enc_out = None
    if cfg.family == "encdec":
        enc_out = MDL._encode(params, cfg, extra["frames"], remat="none")
    cache = MDL.init_cache(cfg, B, 16, jnp.float32, enc_out=enc_out,
                           params=params)
    tok = tokens[:, :1]
    out = [int(tok[0, 0])]
    for pos in range(8):
        logits, cache = MDL.decode_step(params, cfg, cache, tok,
                                        jnp.int32(pos))
        tok = logits[:, -1:].argmax(-1).astype(jnp.int32)
        out.append(int(tok[0, 0]))
    print("decoded token ids:", out)


if __name__ == "__main__":
    main()
