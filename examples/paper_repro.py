"""Reproduce the paper's headline numbers in one command.

    PYTHONPATH=src:. python examples/paper_repro.py [--fast]

Runs the analytic model (Fig 2a), the TLM simulation for a k-sweep with
interference (Fig 3a / Table 5) and the beacon-count analysis (Fig 3b),
printing measured-vs-paper values.
"""
import argparse

import numpy as np

from repro.core import analytic as A
from repro.core import workloads as W
from repro.core.sim import SimParams, response_times, run as sim_run, speedup

PAPER_T5 = {1: 28.1, 8: 73.5, 16: 78.7, 256: 44.3}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="shorter sim (noisier numbers)")
    args = ap.parse_args()
    sim_len = 1e6 if args.fast else 4e6

    print("== Fig 2a (analytic): optimal cluster count ==")
    for cs in (1.0, 8.0, 64.0):
        k = A.optimal_k(256, 256, A.TimingParams(c_s=cs))
        print(f"  c_s={cs:5.1f}: optimal k = {k}   (paper: 32-64 for the "
              f"recursive startup)")

    print("== Table 5 (TLM simulation, interference) ==")
    ours = {}
    for k in PAPER_T5:
        p = SimParams(m=256, k=k, n_childs=100, dn_th=4, max_apps=512,
                      queue_cap=2048)
        arr, gmns, lens = W.interference(p, sim_len=sim_len, seed=1)
        st = sim_run(p, arr, gmns, lens, sim_len)
        s = float(speedup(st, lens))
        n = int(response_times(st)[1].sum())
        ours[k] = s
        print(f"  k={k:3d}: ours={s:6.1f}  paper={PAPER_T5[k]:5.1f}  "
              f"(apps={n}, beacons={int(st['beacons_tx'])})")
    print(f"  ratio k16/k1: ours={ours[16]/ours[1]:.2f}  "
          f"paper={PAPER_T5[16]/PAPER_T5[1]:.2f}")

    print("== Fig 3b (beacon traffic vs threshold) ==")
    for k in (16, 32):
        row = []
        for th in (1, 4, 16):
            p = SimParams(m=256, k=k, n_childs=100, dn_th=th, max_apps=512,
                          queue_cap=2048)
            arr, gmns, lens = W.interference(p, sim_len=sim_len, seed=1)
            st = sim_run(p, arr, gmns, lens, sim_len)
            row.append(int(st["beacons_tx"]))
        print(f"  k={k}: beacons @ dn_th in (1,4,16) = {row}")


if __name__ == "__main__":
    main()
