"""Pallas TPU kernel for the paper's two-stage mapping decision.

The paper models each mapping decision as an RB-tree min-search with cost
``Omega_s = c_s * log(nu)`` on a scalar stack-machine GMN.  On TPU the
pointer-chasing log-search has no analogue; the TPU-native adaptation is a
lane-parallel reduction: the whole (k x m/k) load matrix lives in VMEM and a
fused kernel performs BOTH stages of the paper's hierarchy per decision —
stage 1: argmin over per-cluster load sums, stage 2: argmin inside the
winning cluster — then applies the load update in-place, sequentially for a
batch of T tasks (the sequential dependence is fundamental: decision t+1
must see the load of decision t, exactly like the paper's GMN pipeline).

This is the batch mapping path: `core/mapping.map_batch` routes here
through `kernels.ops.assign_tasks` (compiled on TPU, ``interpret=True``
everywhere else), and `tests/test_kernels_minsearch.py` pins it
decision-for-decision — tie cases included — to the pure-JAX oracle
`kernels.ref.assign_tasks_ref`.  The wall-clock serving engine
(`repro.serving.engine`) makes the same two-stage decision per request
through the numpy adapters in `core/policies.py`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _assign_kernel(loads_ref, costs_ref, assign_ref, out_loads_ref, *, n_tasks):
    loads = loads_ref[...].astype(jnp.float32)            # (k, m_per_k)
    k, mk = loads.shape
    row_ids = jax.lax.broadcasted_iota(jnp.int32, (k, mk), 0)
    col_ids = jax.lax.broadcasted_iota(jnp.int32, (k, mk), 1)

    def step(t, loads):
        csum = loads.sum(axis=1)                          # stage 1: cluster sums
        c = jnp.argmin(csum).astype(jnp.int32)
        in_c = row_ids == c
        masked = jnp.where(in_c, loads, jnp.inf)          # stage 2: inside cluster
        p = jnp.argmin(masked.min(axis=0)).astype(jnp.int32)
        assign_ref[t, 0] = c
        assign_ref[t, 1] = p
        hit = jnp.logical_and(in_c, col_ids == p)
        return loads + jnp.where(hit, costs_ref[t].astype(jnp.float32), 0.0)

    out_loads_ref[...] = jax.lax.fori_loop(0, n_tasks, step, loads)


@functools.partial(jax.jit, static_argnames=("interpret",))
def assign_tasks(loads, costs, *, interpret=False):
    """Map T tasks onto a (k, m_per_k) load matrix by two-stage min-search.

    Returns (assignments (T,2) int32, updated loads).
    """
    T = costs.shape[0]
    kernel = functools.partial(_assign_kernel, n_tasks=T)
    return pl.pallas_call(
        kernel,
        grid=(),
        in_specs=[
            pl.BlockSpec(loads.shape, lambda: (0,) * loads.ndim),
            pl.BlockSpec(costs.shape, lambda: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((T, 2), lambda: (0, 0)),
            pl.BlockSpec(loads.shape, lambda: (0,) * loads.ndim),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, 2), jnp.int32),
            jax.ShapeDtypeStruct(loads.shape, jnp.float32),
        ],
        interpret=interpret,
    )(loads.astype(jnp.float32), costs)
