"""Pure-jnp oracles for every kernel.  Small-shape, numerically transparent.

These are the correctness ground truth for the Pallas kernels (swept in
``tests/test_kernels_*``) and the default execution path on small shapes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True, sliding_window=0):
    """Naive softmax attention.  q (B,Sq,Hq,D); k,v (B,Skv,Hkv,D) with GQA."""
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    qf = q.astype(jnp.float32).transpose(0, 2, 1, 3).reshape(B, Hkv, G, Sq, D)
    kf = k.astype(jnp.float32).transpose(0, 2, 1, 3)
    vf = v.astype(jnp.float32).transpose(0, 2, 1, 3)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf) * scale
    qpos = jnp.arange(Sq)[:, None] + (Skv - Sq)   # align ends (prefill continuation)
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= qpos >= kpos
    if sliding_window:
        mask &= (qpos - kpos) < sliding_window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
    return o.reshape(B, Hkv * G, Sq, D).transpose(0, 2, 1, 3).astype(q.dtype)


def decode_attention_ref(q, cache_k, cache_v, pos, *, lengths=None,
                         sliding_window=0):
    """One-step decode.  q (B,1,Hq,D); cache (B,S,Hkv,D); pos scalar int.

    Attends to cache positions <= pos (the current token's k/v must already
    be written at index ``pos``).  ``lengths`` (B,) optionally overrides pos
    per batch row (continuous batching).
    """
    B, S, Hkv, D = cache_k.shape
    Hq = q.shape[2]
    G = Hq // Hkv
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, D)
    kf = cache_k.astype(jnp.float32).transpose(0, 2, 1, 3)   # (B,Hkv,S,D)
    vf = cache_v.astype(jnp.float32).transpose(0, 2, 1, 3)
    s = jnp.einsum("bhgd,bhkd->bhgk", qf, kf) * scale        # (B,Hkv,G,S)
    kpos = jnp.arange(S)
    limit = (lengths[:, None] if lengths is not None
             else jnp.full((B, 1), pos))                     # inclusive index
    valid = kpos[None, :] <= limit                           # (B,S)
    if sliding_window:
        valid &= kpos[None, :] > (limit - sliding_window)
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bhkd->bhgd", p, vf)
    return o.reshape(B, 1, Hq, D).astype(q.dtype)


def selective_scan_ref(x, dt, A, Bc, Cc, D_skip):
    """Mamba-1 selective scan, naive sequential oracle.

    x, dt: (B,S,Di);  A: (Di,N);  Bc, Cc: (B,S,N);  D_skip: (Di,)
    returns y (B,S,Di).
    """
    Bsz, S, Di = x.shape
    N = A.shape[1]
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    Bf, Cf = Bc.astype(jnp.float32), Cc.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp                       # (B,Di),(B,Di),(B,N),(B,N)
        da = jnp.exp(dtt[..., None] * Af[None])     # (B,Di,N)
        dbx = (dtt * xt)[..., None] * bt[:, None, :]
        h = da * h + dbx
        y = jnp.einsum("bdn,bn->bd", h, ct)
        return h, y

    h0 = jnp.zeros((Bsz, Di, N), jnp.float32)
    xs = (xf.transpose(1, 0, 2), dtf.transpose(1, 0, 2),
          Bf.transpose(1, 0, 2), Cf.transpose(1, 0, 2))
    _, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2) + xf * D_skip.astype(jnp.float32)[None, None]
    return y.astype(x.dtype)


def ssm_decode_ref(h, x, dt, A, Bc, Cc, D_skip):
    """Single decode step of the selective scan.  h (B,Di,N) carries state."""
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    da = jnp.exp(dtf[..., None] * A.astype(jnp.float32)[None])
    dbx = (dtf * xf)[..., None] * Bc.astype(jnp.float32)[:, None, :]
    h = da * h + dbx
    y = jnp.einsum("bdn,bn->bd", h, Cc.astype(jnp.float32))
    y = y + xf * D_skip.astype(jnp.float32)[None]
    return h, y.astype(x.dtype)


def hier_minsearch_ref(loads):
    """Two-stage mapping decision: loads (k, m_per_k) -> (cluster, pe)."""
    cluster = jnp.argmin(loads.sum(axis=1))
    pe = jnp.argmin(loads[cluster])
    return cluster, pe


def assign_tasks_ref(loads, costs):
    """Sequentially map T tasks by two-stage min-search (the paper's mapper).

    loads (k, m_per_k) float32; costs (T,) float32.
    Returns (assignments (T,2) int32, final loads).
    """
    def step(loads, cost):
        c, p = hier_minsearch_ref(loads)
        loads = loads.at[c, p].add(cost)
        return loads, jnp.stack([c, p]).astype(jnp.int32)

    loads, assigns = jax.lax.scan(step, loads.astype(jnp.float32), costs)
    return assigns, loads
