"""Pallas TPU kernels + XLA fallbacks.  See ops.py for the public API."""
