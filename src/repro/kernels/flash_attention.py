"""Pallas TPU flash attention (forward) with GQA / causal / sliding window.

TPU-native adaptation: online-softmax blockwise attention with explicit
BlockSpec VMEM tiling.  The GQA group indirection happens in the index maps
(no materialized KV repeat, unlike the XLA fallback path).  MXU-aligned
block sizes (multiples of 128 on the contracting/lane dims).

Validated on CPU with ``interpret=True`` against ``ref.attention_ref``
(tests/test_kernels_flash.py); compiled path targets TPU v5e.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale, block_q, block_k, causal, sliding_window, seq_q, seq_k):
    """Grid: (batch, q_head, num_q_blocks, num_k_blocks); k innermost."""
    qi = pl.program_id(2)
    kj = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Positions: ends aligned (supports Sq < Sk for chunked prefill).
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0) \
        + (seq_k - seq_q)
    k_pos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    # Whole block out of range? (causal above diagonal / outside window)
    block_needed = jnp.bool_(True)
    if causal:
        block_needed &= (kj * block_k) <= (
            qi * block_q + block_q - 1 + (seq_k - seq_q))
    if sliding_window:
        block_needed &= (kj * block_k + block_k - 1) > (
            qi * block_q + (seq_k - seq_q) - sliding_window)

    @pl.when(block_needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)           # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)           # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = k_pos < seq_k
        if causal:
            mask &= q_pos >= k_pos
        if sliding_window:
            mask &= (q_pos - k_pos) < sliding_window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(kj == nk - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)               # fully-masked rows -> 0
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "sliding_window", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, sliding_window=0,
                    block_q=128, block_k=128, interpret=False):
    """q (B,Sq,Hq,D); k,v (B,Skv,Hkv,D) -> (B,Sq,Hq,D)."""
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    assert Hq % Hkv == 0
    group = Hq // Hkv
    scale = 1.0 / math.sqrt(D)

    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    pad_q = (-Sq) % block_q
    pad_k = (-Skv) % block_k
    qt = jnp.pad(q.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    kt = jnp.pad(k.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    vt = jnp.pad(v.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq = qt.shape[2] // block_q
    nk = kt.shape[2] // block_k

    kernel = functools.partial(
        _fa_kernel, scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, sliding_window=sliding_window, seq_q=Sq, seq_k=Skv)

    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, nq * block_q, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out[:, :, :Sq].transpose(0, 2, 1, 3)
