"""Pallas TPU Mamba-1 selective scan.

TPU-native adaptation of the CUDA selective-scan: the GPU kernel parallelizes
over (batch, channel) threads with a sequential time loop in registers.  On
TPU we tile channels into VPU-lane-aligned blocks (bd x N state tiles live in
VMEM scratch), run chunks of the sequence per grid step, and exploit the
sequential-grid guarantee of the TPU 'arbitrary' dimension to carry the SSM
state across chunks without HBM round-trips.

Validated with ``interpret=True`` against ``ref.selective_scan_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams in jax 0.5; support both
_compiler_params = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams


def _scan_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, h_scr, *,
                 chunk, block_d, n_state):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[...].astype(jnp.float32)               # (bd, N)
    d_skip = d_ref[...].astype(jnp.float32)          # (bd,)

    def step(t, h):
        xt = x_ref[0, t].astype(jnp.float32)         # (bd,)
        dtt = dt_ref[0, t].astype(jnp.float32)       # (bd,)
        bt = b_ref[0, t].astype(jnp.float32)         # (N,)
        ct = c_ref[0, t].astype(jnp.float32)         # (N,)
        da = jnp.exp(dtt[:, None] * a)               # (bd, N)
        h = da * h + (dtt * xt)[:, None] * bt[None, :]
        y = (h * ct[None, :]).sum(axis=1) + d_skip * xt
        y_ref[0, t] = y.astype(y_ref.dtype)
        return h

    h_scr[...] = jax.lax.fori_loop(0, chunk, step, h_scr[...])


@functools.partial(jax.jit, static_argnames=("chunk", "block_d", "interpret"))
def selective_scan(x, dt, A, Bc, Cc, D_skip, *, chunk=128, block_d=256,
                   interpret=False):
    """x, dt (B,S,Di); A (Di,N); Bc, Cc (B,S,N); D_skip (Di,) -> y (B,S,Di)."""
    B, S, Di = x.shape
    N = A.shape[1]
    chunk = min(chunk, S)
    block_d = min(block_d, Di)
    assert S % chunk == 0 and Di % block_d == 0
    nc = S // chunk
    nd = Di // block_d

    kernel = functools.partial(_scan_kernel, chunk=chunk, block_d=block_d,
                               n_state=N)
    # grid: (batch, channel-block) parallel, chunks sequential innermost so
    # the state scratch legitimately carries across chunk steps.
    return pl.pallas_call(
        kernel,
        grid=(B, nd, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),  # x
            pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),  # dt
            pl.BlockSpec((block_d, N), lambda b, d, c: (d, 0)),            # A
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),        # B
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),        # C
            pl.BlockSpec((block_d,), lambda b, d, c: (d,)),                # D
        ],
        out_specs=pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),
        out_shape=jax.ShapeDtypeStruct((B, S, Di), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_d, N), jnp.float32)],
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(x, dt, A, Bc, Cc, D_skip)
