"""Public jit'd kernel wrappers with backend dispatch.

On TPU the Pallas kernels run; elsewhere (this CPU container, and for any
shape the kernels don't cover) a memory-safe chunked-XLA implementation with
identical math executes.  ``flash_attention_xla`` is a custom-VJP online-
softmax attention (flash fwd + flash bwd) so 32k+ sequences never
materialize the (Sq x Skv) score matrix and the backward saves only
(q, k, v, out, lse) — this is the path the multi-pod dry-run lowers.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref

_INTERPRET_PALLAS = False   # tests flip this to exercise kernels on CPU


def on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


# ==========================================================================
# Flash attention (XLA chunked, custom VJP)
# ==========================================================================

_DEF_CHUNK = 512


def _mask(qpos, kpos, causal, window, seq_k):
    m = kpos < seq_k
    if causal:
        m &= qpos >= kpos
    if window:
        m &= (qpos - kpos) < window
    return m


def _fa_fwd_scan(q, k, v, causal, window, chunk):
    """q (B,Hkv,G,Sq,D); k,v (B,Hkv,Skv,D) -> out, lse (f32)."""
    B, Hkv, G, Sq, D = q.shape
    Skv = k.shape[2]
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    nck = -(-Skv // chunk)
    pad = nck * chunk - Skv
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kp = kp.reshape(B, Hkv, nck, chunk, D).transpose(2, 0, 1, 3, 4)
    vp = vp.reshape(B, Hkv, nck, chunk, D).transpose(2, 0, 1, 3, 4)
    qpos = (jnp.arange(Sq) + (Skv - Sq))[:, None]

    def body(carry, inp):
        acc, m, l = carry
        j, kc, vc = inp
        s = jnp.einsum("bhgqd,bhcd->bhgqc", q, kc,
                       preferred_element_type=jnp.float32) * scale
        kpos = j * chunk + jnp.arange(chunk)[None, :]
        s = jnp.where(_mask(qpos, kpos, causal, window, Skv)[None, None, None],
                      s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        # rows with all -inf so far keep m=-inf; exp(-inf - -inf) guarded:
        alpha = jnp.exp(jnp.where(m == -jnp.inf, -jnp.inf, m - m_new))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(jnp.isnan(p), 0.0, p)
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgqc,bhcd->bhgqd", p.astype(vc.dtype), vc,
            preferred_element_type=jnp.float32)
        return (acc, m_new, l), None

    acc0 = jnp.zeros((B, Hkv, G, Sq, D), jnp.float32)
    m0 = jnp.full((B, Hkv, G, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(
        body, (acc0, m0, l0), (jnp.arange(nck), kp, vp))
    lse = m + jnp.log(jnp.where(l == 0, 1.0, l))
    out = acc / jnp.where(l == 0, 1.0, l)[..., None]
    return out, lse


def _fa_bwd_scan(q, k, v, out, lse, dout, causal, window, chunk):
    B, Hkv, G, Sq, D = q.shape
    Skv = k.shape[2]
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    nck = -(-Skv // chunk)
    pad = nck * chunk - Skv
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kp = kp.reshape(B, Hkv, nck, chunk, D).transpose(2, 0, 1, 3, 4)
    vp = vp.reshape(B, Hkv, nck, chunk, D).transpose(2, 0, 1, 3, 4)
    qpos = (jnp.arange(Sq) + (Skv - Sq))[:, None]
    # out is saved in compute dtype (bf16); accumulate delta in f32
    delta = jnp.einsum("bhgqd,bhgqd->bhgq", dout, out,
                       preferred_element_type=jnp.float32)

    def body(dq, inp):
        j, kc, vc = inp
        s = jnp.einsum("bhgqd,bhcd->bhgqc", q, kc,
                       preferred_element_type=jnp.float32) * scale
        kpos = j * chunk + jnp.arange(chunk)[None, :]
        msk = _mask(qpos, kpos, causal, window, Skv)[None, None, None]
        p = jnp.exp(s - lse[..., None])
        p = jnp.where(msk, p, 0.0)
        dv = jnp.einsum("bhgqc,bhgqd->bhcd", p, dout,
                        preferred_element_type=jnp.float32)
        dp = jnp.einsum("bhgqd,bhcd->bhgqc", dout, vc,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - delta[..., None]) * scale
        dq = dq + jnp.einsum("bhgqc,bhcd->bhgqd", ds.astype(kc.dtype), kc,
                             preferred_element_type=jnp.float32)
        dk = jnp.einsum("bhgqc,bhgqd->bhcd", ds.astype(q.dtype), q,
                        preferred_element_type=jnp.float32)
        return dq, (dk, dv)

    dq0 = jnp.zeros((B, Hkv, G, Sq, D), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(body, dq0, (jnp.arange(nck), kp, vp))
    dk = dks.transpose(1, 2, 0, 3, 4).reshape(B, Hkv, nck * chunk, D)[:, :, :Skv]
    dv = dvs.transpose(1, 2, 0, 3, 4).reshape(B, Hkv, nck * chunk, D)[:, :, :Skv]
    return dq, dk, dv


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention_xla(q, k, v, causal=True, window=0, chunk=_DEF_CHUNK):
    out, _ = _fa_fwd_scan(q, k, v, causal, window, chunk)
    return out.astype(q.dtype)


def _fa_vjp_fwd(q, k, v, causal, window, chunk):
    out, lse = _fa_fwd_scan(q, k, v, causal, window, chunk)
    out = out.astype(q.dtype)
    # residuals stay in compute dtype: an f32 `out` here gets stacked per
    # layer by the training scan (+10GB/chip on qwen2-72b; see §Perf)
    return out, (q, k, v, out, lse)


def _fa_vjp_bwd(causal, window, chunk, res, dout):
    q, k, v, out, lse = res
    dq, dk, dv = _fa_bwd_scan(q, k, v, out, lse, dout.astype(q.dtype),
                              causal, window, chunk)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention_xla.defvjp(_fa_vjp_fwd, _fa_vjp_bwd)


# ==========================================================================
# Dispatchers
# ==========================================================================

def attention(q, k, v, *, causal=True, sliding_window=0):
    """q (B,Sq,Hq,D); k,v (B,Skv,Hkv,D) -> (B,Sq,Hq,D)."""
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    if on_tpu() and Sq >= 128 and Skv >= 128:
        from repro.kernels.flash_attention import flash_attention
        return flash_attention(q, k, v, causal=causal,
                               sliding_window=sliding_window)
    if max(Sq, Skv) <= 1024:
        return _ref.attention_ref(q, k, v, causal=causal,
                                  sliding_window=sliding_window)
    G = Hq // Hkv
    qg = q.transpose(0, 2, 1, 3).reshape(B, Hkv, G, Sq, D)
    kg = k.transpose(0, 2, 1, 3)
    vg = v.transpose(0, 2, 1, 3)
    out = flash_attention_xla(qg, kg, vg, causal, sliding_window,
                              min(_DEF_CHUNK, Skv))
    return out.reshape(B, Hq, Sq, D).transpose(0, 2, 1, 3)


def decode_attention(q, cache_k, cache_v, pos, *, lengths=None,
                     sliding_window=0):
    """Single-token decode over a (possibly ring-buffered) KV cache."""
    return _ref.decode_attention_ref(q, cache_k, cache_v, pos,
                                     lengths=lengths,
                                     sliding_window=sliding_window)


def decode_attention_partial(q, cache_k, cache_v, valid):
    """Per-shard partial attention stats for sequence-parallel decode.

    q (B,1,Hq,D); cache (B,Sloc,Hkv,D); valid (B,Sloc) bool.
    Returns (acc (B,Hq,D) f32 unnormalized, m (B,Hq) f32, l (B,Hq) f32) —
    combined across shards by ``parallel.sp.sp_decode_attention``.
    """
    B, Sloc, Hkv, D = cache_k.shape
    Hq = q.shape[2]
    G = Hq // Hkv
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, D)
    kf = cache_k.astype(jnp.float32).transpose(0, 2, 1, 3)
    vf = cache_v.astype(jnp.float32).transpose(0, 2, 1, 3)
    s = jnp.einsum("bhgd,bhkd->bhgk", qf, kf) * scale
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(jnp.isnan(p), 0.0, p)           # all-masked shard
    l = p.sum(axis=-1)
    acc = jnp.einsum("bhgk,bhkd->bhgd", p, vf)
    return (acc.reshape(B, Hq, D), m.reshape(B, Hq), l.reshape(B, Hq))


def selective_scan(x, dt, A, Bc, Cc, D_skip, *, chunk=128):
    """Mamba-1 scan.  Chunked associative scan on XLA; Pallas kernel on TPU."""
    if on_tpu() and x.shape[1] % chunk == 0 and x.shape[2] % 256 == 0:
        from repro.kernels.selective_scan import selective_scan as pallas_scan
        return pallas_scan(x, dt, A, Bc, Cc, D_skip, chunk=chunk)
    return _chunked_selective_scan(x, dt, A, Bc, Cc, D_skip, chunk=chunk)


def _chunked_selective_scan(x, dt, A, Bc, Cc, D_skip, *, chunk=128):
    """Vectorized scan: outer lax.scan over chunks, inner associative scan.

    Never materializes (B,S,Di,N); peak intermediate is (B,chunk,Di,N).
    """
    B, S, Di = x.shape
    N = A.shape[1]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bc = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
        Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
    nc = (S + pad) // chunk
    xs = x.reshape(B, nc, chunk, Di).transpose(1, 0, 2, 3)
    dts = dt.reshape(B, nc, chunk, Di).transpose(1, 0, 2, 3)
    bs = Bc.reshape(B, nc, chunk, N).transpose(1, 0, 2, 3)
    cs = Cc.reshape(B, nc, chunk, N).transpose(1, 0, 2, 3)
    Af = A.astype(jnp.float32)
    Df = D_skip.astype(jnp.float32)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    def chunk_step(h, inp):
        xc, dtc, bc, cc = inp                         # (B,chunk,*)
        dtf = dtc.astype(jnp.float32)
        da = jnp.exp(dtf[..., None] * Af[None, None])             # (B,L,Di,N)
        dbx = (dtf * xc.astype(jnp.float32))[..., None] * bc.astype(
            jnp.float32)[:, :, None, :]
        a_all, h_all = jax.lax.associative_scan(combine, (da, dbx), axis=1)
        h_all = h_all + a_all * h[:, None]            # inject carry-in state
        y = jnp.einsum("bldn,bln->bld", h_all, cc.astype(jnp.float32))
        y = y + xc.astype(jnp.float32) * Df[None, None]
        return h_all[:, -1], y.astype(x.dtype)

    h0 = jnp.zeros((B, Di, N), jnp.float32)
    # remat the chunk body: AD saves only the (B,Di,N) carry per chunk and
    # recomputes the (B,chunk,Di,N) intermediates in the backward pass.
    _, ys = jax.lax.scan(jax.checkpoint(chunk_step), h0, (xs, dts, bs, cs))
    y = ys.transpose(1, 0, 2, 3).reshape(B, nc * chunk, Di)
    return y[:, :S] if pad else y


def ssm_decode(h, x, dt, A, Bc, Cc, D_skip):
    return _ref.ssm_decode_ref(h, x, dt, A, Bc, Cc, D_skip)


def assign_tasks(loads, costs):
    """Two-stage min-search task mapping (paper Sec 4.1).

    Always routes through the Pallas kernel — compiled on TPU,
    ``interpret=True`` elsewhere — so the batch mapping path exercises
    the exact kernel the hardware runs (decision-for-decision equal to
    the pure-JAX oracle, tests/test_kernels_minsearch.py)."""
    from repro.kernels.hier_minsearch import assign_tasks as pallas_assign
    return pallas_assign(loads, costs, interpret=not on_tpu())
