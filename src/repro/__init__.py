"""repro: clustered hierarchical task management for multi-pod JAX systems."""

__version__ = "0.1.0"
