"""Parameter & cache PartitionSpec rules (DP/TP/EP/SP).

Rules are derived from pytree paths + array shapes, per architecture:

- attention: q/o projections column/row-parallel over "model" when n_heads
  divides the axis; k/v likewise when n_kv_heads divides (else replicated —
  GQA with few KV heads, e.g. glm4 kv=2).
- MLP: hidden dim over "model" (column then row parallel).
- MoE: expert axis over "model" when E divides it (expert parallelism),
  else per-expert hidden dim over "model" (TP inside experts).
- embeddings: vocab over "model".
- Mamba: d_inner over "model".
- batch over dp axes ("pod","data") for train; "data" for decode.
- KV caches: batch over "data" when divisible, else sequence over "data"
  (sequence parallelism for long_500k, batch=1).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name]


def _p(*spec):
    return P(*spec)


def param_spec(cfg: ModelConfig, mesh: Mesh, path: str, shape) -> P:
    """PartitionSpec for one parameter, by name and shape."""
    tp = _axis_size(mesh, "model")
    nq, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    # strip scan-stacking: any leading n_super axis is replicated; rules below
    # index from the END of the shape.
    r = len(shape)

    def last(spec_tail):
        return P(*([None] * (r - len(spec_tail)) + list(spec_tail)))

    leaf = path.split("/")[-1]
    parent = path.split("/")[-2] if "/" in path else ""

    if leaf == "tok" or leaf == "out" and parent == "embed":
        # (V, d) / (d, V): shard vocab axis
        big = int(np.argmax(shape[-2:]))
        return last(["model", None] if big == 0 else [None, "model"])
    if parent == "attn" or parent == "cross":
        if leaf == "wq":
            return last([None, "model"]) if nq % tp == 0 else last([None, None])
        if leaf in ("wk", "wv"):
            return last([None, "model"]) if nkv % tp == 0 else last([None, None])
        if leaf == "wo":
            return last(["model", None]) if nq % tp == 0 else last([None, None])
        if leaf == "bq":
            return last(["model"]) if nq % tp == 0 else last([None])
        if leaf in ("bk", "bv"):
            return last(["model"]) if nkv % tp == 0 else last([None])
    if parent == "mlp" or parent == "shared":
        if leaf in ("wg", "wu"):
            return last([None, "model"])
        if leaf == "wd":
            return last(["model", None])
    if parent == "moe":
        E = cfg.moe.n_experts
        if leaf == "router":
            return last([None, None])
        if leaf in ("wg", "wu"):
            return last(["model", None, None]) if E % tp == 0 \
                else last([None, None, "model"])
        if leaf == "wd":
            return last(["model", None, None]) if E % tp == 0 \
                else last([None, "model", None])
    if parent == "mamba":
        if leaf in ("in_x", "in_z"):
            return last([None, "model"])
        if leaf == "out_proj":
            return last(["model", None])
        if leaf in ("conv_w", "conv_b", "dt_bias", "D"):
            return last(["model"]) if len(shape) >= 1 and shape[-1] % tp == 0 \
                else last([None])
        if leaf == "A_log":
            return last(["model", None])
        if leaf == "x_proj":
            return last(["model", None])
        if leaf == "dt_proj":
            return last([None, "model"])
    if leaf == "vision_adapter":
        return last([None, "model"])
    # norms, router, small vectors: replicate
    return P()


def _tree_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for kp, leaf in flat:
        parts = []
        for k in kp:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            else:
                parts.append(str(k))
        out.append(("/".join(parts), leaf))
    return out, treedef


def _add_fsdp(spec: P, shape, dp_axes: tuple, dp_size: int) -> P:
    """ZeRO-3: additionally shard the largest free dim over the DP axes.

    GSPMD inserts the per-layer all-gather (fwd) / reduce-scatter (bwd)
    automatically; without this, replicated params + fp32 Adam state
    overflow HBM for the >50B archs.
    """
    entries = list(spec) + [None] * (len(shape) - len(spec))
    cand, cand_sz = -1, 0
    for i, (e, s) in enumerate(zip(entries, shape)):
        if e is None and s % dp_size == 0 and s >= 1024 and s > cand_sz:
            cand, cand_sz = i, s
    if cand >= 0:
        entries[cand] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    return P(*entries)


def param_shardings(cfg: ModelConfig, mesh: Mesh, params_shape,
                    *, fsdp: bool = True, tp: bool = True) -> Any:
    """NamedSharding pytree matching a params (shape) pytree.

    tp=False is the pure-ZeRO-3 layout: no tensor parallelism at all,
    every parameter sharded over ALL mesh axes on its largest dim — zero
    in-layer activation collectives, one param all-gather per layer.
    Wins when tokens-per-chip is small (see EXPERIMENTS.md §Perf D1)."""
    names = mesh.axis_names
    dp_ax = ("pod", "data") if "pod" in names else ("data",)
    if not tp:
        dp_ax = dp_ax + ("model",)
    dp_size = 1
    for a in dp_ax:
        dp_size *= mesh.shape[a]
    flat, treedef = _tree_paths(params_shape)
    specs = []
    for path, leaf in flat:
        spec = param_spec(cfg, mesh, path, leaf.shape) if tp else P()
        if fsdp:
            spec = _add_fsdp(spec, leaf.shape, dp_ax, dp_size)
        specs.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, specs)


def cache_spec(cfg: ModelConfig, mesh: Mesh, path: str, shape,
               *, batch: int) -> P:
    """Decode-cache sharding: DP over batch when divisible, else SP over seq."""
    names = mesh.axis_names
    dp_names = ("pod", "data") if "pod" in names else ("data",)
    dp = 1
    for a in dp_names:
        dp *= _axis_size(mesh, a)
    dp_entry = dp_names if len(dp_names) > 1 else dp_names[0]
    tp = _axis_size(mesh, "model")
    leaf = path.split("/")[-1]
    r = len(shape)

    def last(spec_tail):
        return P(*([None] * (r - len(spec_tail)) + list(spec_tail)))

    if leaf in ("k", "v", "ck", "cv"):          # (B, S, nkv, hd)
        # heads shard over "model" only when divisible (GQA often isn't);
        # leftover axes shard the SEQUENCE dim — decode attention over a
        # seq-sharded cache distributes flash-decoding style (partial
        # softmax + tiny stat all-reduces, inserted by GSPMD).
        kv_ax = "model" if cfg.n_kv_heads % tp == 0 else None
        batch_ax = dp_entry if batch % dp == 0 else None
        seq_axes = []
        S = shape[-3]
        if batch_ax is None and S % dp == 0:
            seq_axes.extend(dp_names)
        if kv_ax is None and S % (tp * max(dp if seq_axes else 1, 1)) == 0:
            seq_axes.append("model")
        seq_entry = (tuple(seq_axes) if len(seq_axes) > 1
                     else (seq_axes[0] if seq_axes else None))
        return last([batch_ax, seq_entry, kv_ax, None])
    if leaf == "h":                              # (B, d_in, N) mamba state
        din_ax = "model" if shape[-2] % tp == 0 else None
        if batch % dp == 0:
            return last([dp_entry, din_ax, None])
        return last([None, din_ax, None])
    if leaf == "conv":                           # (B, d_conv-1, d_in)
        din_ax = "model" if shape[-1] % tp == 0 else None
        if batch % dp == 0:
            return last([dp_entry, None, din_ax])
        return last([None, None, din_ax])
    return P()


def cache_shardings(cfg: ModelConfig, mesh: Mesh, cache_shape, batch) -> Any:
    flat, treedef = _tree_paths(cache_shape)
    specs = [NamedSharding(mesh, cache_spec(cfg, mesh, path, leaf.shape,
                                            batch=batch))
             for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_spec(mesh: Mesh, *, multi_pod: bool) -> P:
    return P(("pod", "data") if multi_pod else "data")
