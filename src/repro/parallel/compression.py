"""int8 gradient compression with error feedback.

Quantize per-leaf to int8 with a per-leaf scale before the cross-pod
gradient reduction, keep the quantization residual locally and add it back
next step (error feedback — keeps SGD unbiased in the long run).  Applied
around the optimizer in launch/train.py when RunConfig.grad_compression ==
"int8"; reduces inter-pod gradient bytes 4x (f32) / 2x (bf16).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error_state(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def quantize(g, err):
    """-> (int8 payload, scale, new local residual)."""
    gf = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    residual = gf - q.astype(jnp.float32) * scale
    return q, scale, residual


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compress_grads(grads, err_state):
    """Per-leaf quantize/dequantize with error feedback.

    Under pjit the int8 payload is what crosses the slow (inter-pod) links:
    XLA reduces the dequantized values, but marking the quantize boundary
    with this transformation keeps the communicated tensor int8 when the
    reduction is sharded pod-major (see EXPERIMENTS.md §Perf for the
    measured collective-byte delta)."""
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(err_state)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        q, s, r = quantize(g, e)
        out_g.append(dequantize(q, s).astype(g.dtype))
        out_e.append(r)
    return (jax.tree_util.tree_unflatten(tdef, out_g),
            jax.tree_util.tree_unflatten(tdef, out_e))
