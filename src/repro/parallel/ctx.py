"""Sharding-hint context: model code stays mesh-agnostic.

``shard_hint(x, name)`` applies ``jax.lax.with_sharding_constraint`` when a
sharding context is active (set by launch/steps.py under a mesh) and is the
identity otherwise (CPU smoke tests, single device).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_state = threading.local()


def _rules() -> Optional[dict]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def sharding_rules(mesh, rules: dict):
    """rules: name -> PartitionSpec (applied to activations by shard_hint)."""
    prev = _rules()
    _state.rules = {k: NamedSharding(mesh, v) for k, v in rules.items()}
    try:
        yield
    finally:
        _state.rules = prev


def shard_hint(x, name: str):
    rules = _rules()
    if rules is None or name not in rules:
        return x
    return jax.lax.with_sharding_constraint(x, rules[name])


def activation_rules(*, dp_axes=("data",), shard_act_embed=True) -> dict:
    """Default activation PartitionSpecs by hint name.

    The saved-between-layers (B,S,d) activations are sharded over BOTH the
    dp axes (batch) and the "model" axis (embed dim, Megatron-SP style):
    remat checkpoints otherwise dominate HBM at 4k seq x 80 layers.
    """
    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    d_ax = "model" if shard_act_embed else None
    return {
        "act_btd": P(dp, None, d_ax),
        "act_btd_decode": P(dp, None, d_ax),
        "logits": P(dp, None, "model"),
        "act_btf": P(dp, None, "model"),
        "act_q": P(dp, None, "model", None),
        "act_kv": P(dp, None, None, None),
    }


def cell_rules(cfg, mesh, *, batch: int, multi_pod: bool,
               layout: str = "tp_fsdp") -> dict:
    """Per-cell activation rules: dp axes include "pod" on the multi-pod
    mesh; head/hidden hints drop "model" where the arch's head counts
    don't divide the axis; batch axes drop out when batch doesn't divide
    (e.g. long_500k batch=1).  layout="zero3": batch shards over EVERY
    axis and no activation dim touches "model" (pure FSDP)."""
    names = mesh.axis_names
    dp_names = ("pod", "data") if (multi_pod and "pod" in names) else ("data",)
    if layout == "zero3":
        dp_names = dp_names + ("model",)
    dp_size = 1
    for a in dp_names:
        dp_size *= mesh.shape[a]
    dp = (dp_names if len(dp_names) > 1 else dp_names[0]) \
        if batch % dp_size == 0 else None
    if layout == "zero3":
        return {name: P(dp, None, None) if name != "act_q" and name != "act_kv"
                else P(dp, None, None, None)
                for name in ("act_btd", "act_btd_decode", "logits",
                             "act_btf", "act_q", "act_kv",
                             "moe_ecd", "moe_ecf")}
    tp = mesh.shape["model"]
    d_ax = "model" if cfg.d_model % tp == 0 else None
    # Megatron-style sequence parallelism for the saved inter-layer
    # activations: sharding S (not d) over "model" turns the backward's
    # input-grad all-reduces into reduce-scatters (§Perf iteration D2).
    seq_sp = layout == "sp"
    if cfg.n_heads % tp == 0:
        act_q = P(dp, None, "model", None)
    else:
        # heads don't divide TP (minicpm 36H): shard the QUERY SEQUENCE over
        # "model" instead (ring-attention data layout, k/v replicated) so
        # attention activations aren't 16x-replicated.  §Perf iteration 1.
        act_q = P(dp, "model", None, None)
    kv_ax = "model" if cfg.n_kv_heads % tp == 0 else None
    rules = {
        "act_btd": P(dp, "model", None) if seq_sp else P(dp, None, d_ax),
        "act_btd_decode": P(dp, None, d_ax),
        "logits": P(dp, None, "model"),
        "act_btf": P(dp, None, "model"),       # FFN hidden (d_ff always | tp)
        "act_q": act_q,
        "act_kv": P(dp, None, kv_ax, None),
        "xent_in": P(dp, d_ax),
    }
    if cfg.moe is not None:
        if cfg.moe.n_experts % tp == 0:
            # expert parallelism: (G,E,C,*) tensors sharded on the E axis —
            # GSPMD turns dispatch/combine into all-to-alls.  (Resharding
            # expert_out E->d before the combine was tried and REFUTED:
            # +5% collective, see §Perf D3.)
            rules["moe_ecd"] = P(dp, "model", None, None)
            rules["moe_ecf"] = P(dp, "model", None, None)
            rules["moe_out"] = P(dp, "model", None, None)
        else:
            # few big experts (mixtral 8e < tp): keep TP inside each expert;
            # the hidden is f-sharded, dispatch stays d-replicated bf16
            rules["moe_ecd"] = P(dp, None, None, None)
            rules["moe_ecf"] = P(dp, None, None, "model")
            rules["moe_out"] = P(dp, None, None, None)
    return rules
