"""Mixture-of-Experts FFN: shared + routed top-k experts (GShard-style
grouped capacity dispatch so FLOPs scale with active — not total — experts
and the dispatch tensor stays O(group_size^2 * K) per group, never O(T^2)).

Covers Mixtral (8e top-2), DeepSeek-MoE (64e top-6 + 2 shared, fine-grained)
and Jamba (16e top-2 every other layer).  Expert weights carry a leading
expert axis so they shard over the "model" mesh axis (expert parallelism)
when E divides the axis, else over the hidden axis (TP inside each expert) —
see parallel/sharding.py.

NOTE (DESIGN.md §4): the paper's min-search mapper is NOT used in-graph for
routing — learned top-k routing is model semantics; the paper's technique
manages run-time work placement.  `repro.core.mapping` is reused offline to
analyze expert balance (benchmarks/moe_balance.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def init_moe(key, cfg: ModelConfig, dtype=jnp.float32):
    m = cfg.moe
    d = cfg.d_model
    e_ff = m.d_ff_expert or cfg.d_ff
    ks = jax.random.split(key, 5)
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype))
    p = {
        "router": jax.random.normal(ks[0], (d, m.n_experts), dtype) * scale,
        "wg": jax.random.normal(ks[1], (m.n_experts, d, e_ff), dtype) * scale,
        "wu": jax.random.normal(ks[2], (m.n_experts, d, e_ff), dtype) * scale,
        "wd": jax.random.normal(ks[3], (m.n_experts, e_ff, d), dtype)
              * (1.0 / jnp.sqrt(jnp.asarray(e_ff, dtype))),
    }
    if m.n_shared:
        from repro.models.layers import init_mlp
        p["shared"] = init_mlp(ks[4], cfg, m.n_shared * e_ff, dtype)
    return p


def apply_moe(params, cfg: ModelConfig, x, *, capacity_factor=1.25,
              group_size=256):
    # group_size: dispatch/combine one-hots are O(cf*K*T*group_size) elems —
    # LINEAR in group size.  512->256 halved MoE activation memory and let
    # mixtral train_4k drop from 8 to 2 microbatches (§Perf iteration M1).
    """x (B,S,d) -> (out (B,S,d), aux dict with router load stats)."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, K = m.n_experts, m.top_k
    if T % group_size != 0:
        group_size = T            # tiny smoke shapes: one group
    G = T // group_size
    Sg = group_size
    xt = x.reshape(G, Sg, d)

    logits = (xt.astype(jnp.float32)
              @ params["router"].astype(jnp.float32))            # (G,Sg,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)               # (G,Sg,K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)                   # renormalize

    # Per-group capacity: each expert accepts at most C tokens per group
    # (ceil so tiny decode groups never drop below top_k coverage).
    C = max(1, -(-int(capacity_factor * Sg * K) // E))
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)       # (G,Sg,K,E)
    flat = onehot.reshape(G, Sg * K, E)
    pos_flat = jnp.cumsum(flat, axis=1) - flat                    # exclusive
    pos = (pos_flat.reshape(G, Sg, K, E) * onehot).sum(-1)        # (G,Sg,K)
    keep = pos < C
    gate_vals = gate_vals * keep

    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, C), C + 1,
                            dtype=x.dtype)[..., :C]               # (G,Sg,K,C)
    disp = jnp.einsum("gske,gskc->gsec",
                      (onehot * keep[..., None]).astype(x.dtype),
                      pos_oh)                                     # (G,Sg,E,C)
    from repro.parallel.ctx import shard_hint
    expert_in = jnp.einsum("gsec,gsd->gecd", disp, xt)            # (G,E,C,d)
    expert_in = shard_hint(expert_in, "moe_ecd")
    g = jnp.einsum("gecd,edf->gecf", expert_in, params["wg"].astype(x.dtype))
    u = jnp.einsum("gecd,edf->gecf", expert_in, params["wu"].astype(x.dtype))
    h = shard_hint(jax.nn.silu(g) * u, "moe_ecf")
    expert_out = jnp.einsum("gecf,efd->gecd", h, params["wd"].astype(x.dtype))
    # reshard E-sharded -> d-sharded (all-to-all) so the combine contracts
    # the expert axis locally instead of all-reducing (G,Sg,d) partial sums
    expert_out = shard_hint(expert_out, "moe_out")
    comb = jnp.einsum("gske,gskc,gsk->gsec",
                      (onehot * keep[..., None]).astype(x.dtype),
                      pos_oh, gate_vals.astype(x.dtype))
    out = jnp.einsum("gsec,gecd->gsd", comb, expert_out)

    if m.n_shared:
        from repro.models.layers import apply_mlp
        out = out + apply_mlp(params["shared"], cfg, xt)

    # aux: load-balance loss terms (Switch-style) + drop fraction
    frac_tokens = onehot.sum(axis=(0, 1, 2)).astype(jnp.float32) / (T * K)
    mean_prob = probs.mean(axis=(0, 1))
    aux = {"load_balance": E * jnp.sum(frac_tokens * mean_prob),
           "dropped_frac": 1.0 - keep.mean(),
           "tokens_per_expert": frac_tokens}
    return out.reshape(B, S, d), aux


def moe_layer_indices(cfg: ModelConfig):
    m = cfg.moe
    if m is None:
        return set()
    return {i for i in range(cfg.n_layers)
            if i >= m.first_dense and (i - m.first_dense) % m.every == 0}
