"""Unified architecture zoo: decoder LMs, hybrid SSM/attention, enc-dec.

One functional model covering all 10 assigned architectures:

- layer plan     : `plan_layers` derives (prefix, periodic super-block) specs
                   so heterogeneous stacks (Jamba 1:7, DeepSeek first-dense)
                   still scan over layers (HLO size O(one super-block)).
- forward        : training / prefill (full sequence)
- decode         : single-token step over per-layer caches (KV ring for SWA,
                   O(1) SSM state for Mamba)
- enc-dec        : Whisper-style encoder + cross-attention decoder
- frontends      : audio/vision are STUBS — precomputed embeddings arrive as
                   inputs (per assignment), optionally through a linear adapter.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import moe as MOE
from repro.models.moe import moe_layer_indices
from repro.parallel.ctx import shard_hint


@jax.custom_vjp
def _opt_barrier(x):
    """``optimization_barrier`` as an identity with an explicit VJP: jax
    0.4.x has no differentiation rule for the primitive, so grad through the
    scan body fails without this.  Forward HLO is unchanged (still a
    barrier); the cotangent gets the same barrier so the backward residual
    stack keeps the same hoisting fence."""
    return jax.lax.optimization_barrier(x)


def _opt_barrier_fwd(x):
    return _opt_barrier(x), None


def _opt_barrier_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


_opt_barrier.defvjp(_opt_barrier_fwd, _opt_barrier_bwd)


# --------------------------------------------------------------------------
# Layer planning
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class LayerSpec:
    mixer: str            # attn | mamba
    ffn: str              # dense | moe | none
    d_ff: int             # hidden size if dense


def layer_spec(cfg: ModelConfig, i: int) -> LayerSpec:
    if cfg.family == "ssm":
        return LayerSpec("mamba", "none", 0)
    if cfg.family == "hybrid":
        mixer = "attn" if i % cfg.hybrid_period == cfg.hybrid_attn_index else "mamba"
    else:
        mixer = "attn"
    moe_set = moe_layer_indices(cfg)
    if cfg.moe is not None and i in moe_set:
        return LayerSpec(mixer, "moe", 0)
    if cfg.moe is not None and i not in moe_set:
        return LayerSpec(mixer, "dense", cfg.moe.d_ff_dense or cfg.d_ff)
    if cfg.d_ff:
        return LayerSpec(mixer, "dense", cfg.d_ff)
    return LayerSpec(mixer, "none", 0)


def plan_layers(cfg: ModelConfig):
    """-> (prefix_specs, period_specs, n_super).  specs[prefix:] is periodic."""
    specs = [layer_spec(cfg, i) for i in range(cfg.n_layers)]
    base = cfg.hybrid_period or 1
    if cfg.moe is not None and cfg.moe.every > 1:
        # period must be a multiple of the MoE interval
        base = base * cfg.moe.every // _gcd(base, cfg.moe.every)
    for prefix in range(0, 3):
        body = specs[prefix:]
        for period in (base, base * 2):
            if len(body) == 0 or len(body) % period:
                continue
            pat = body[:period]
            if all(body[j] == pat[j % period] for j in range(len(body))):
                return specs[:prefix], pat, len(body) // period
    # fall back: no scan (fully unrolled prefix)
    return specs, [], 0


def _gcd(a, b):
    while b:
        a, b = b, a % b
    return a


# --------------------------------------------------------------------------
# Parameter init
# --------------------------------------------------------------------------

def _init_layer(key, cfg: ModelConfig, spec: LayerSpec, dtype):
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = {"norm1": L.init_norm(cfg, dtype)}
    if spec.mixer == "attn":
        p["attn"] = L.init_attention(ks[0], cfg, dtype)
    else:
        p["mamba"] = M.init_mamba(ks[0], cfg, dtype)
    if spec.ffn == "dense":
        p["norm2"] = L.init_norm(cfg, dtype)
        p["mlp"] = L.init_mlp(ks[1], cfg, spec.d_ff, dtype)
    elif spec.ffn == "moe":
        p["norm2"] = L.init_norm(cfg, dtype)
        p["moe"] = MOE.init_moe(ks[1], cfg, dtype)
    return p


def _init_enc_layer(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 2)
    return {"norm1": L.init_norm(cfg, dtype),
            "attn": L.init_attention(ks[0], cfg, dtype),
            "norm2": L.init_norm(cfg, dtype),
            "mlp": L.init_mlp(ks[1], cfg, cfg.d_ff, dtype)}


def _init_dec_cross(key, cfg: ModelConfig, dtype):
    return {"norm_x": L.init_norm(cfg, dtype),
            "cross": L.init_attention(key, cfg, dtype)}


def _stack(trees):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def init_model(key, cfg: ModelConfig, dtype=jnp.bfloat16):
    """Full parameter pytree.  Wrap in jax.eval_shape for the dry-run."""
    prefix, period, n_super = plan_layers(cfg)
    keys = jax.random.split(key, 8)
    params: dict[str, Any] = {"embed": L.init_embedding(keys[0], cfg, dtype)}

    params["prefix"] = [
        _init_layer(jax.random.fold_in(keys[1], i), cfg, s, dtype)
        for i, s in enumerate(prefix)]

    blocks = []
    for b in range(n_super):
        kb = jax.random.fold_in(keys[2], b)
        blocks.append({
            f"l{j}": _init_layer(jax.random.fold_in(kb, j), cfg, s, dtype)
            for j, s in enumerate(period)})
    params["blocks"] = _stack(blocks) if blocks else {}

    params["final_norm"] = L.init_norm(cfg, dtype)

    if cfg.family == "encdec":
        enc = [_init_enc_layer(jax.random.fold_in(keys[3], i), cfg, dtype)
               for i in range(cfg.n_enc_layers)]
        params["enc_blocks"] = _stack(enc)
        params["enc_final_norm"] = L.init_norm(cfg, dtype)
        cross = [_init_dec_cross(jax.random.fold_in(keys[4], i), cfg, dtype)
                 for i in range(cfg.n_layers)]
        # cross-attn params follow the decoder scan structure (period must be 1)
        params["cross_blocks"] = _stack(cross)
    if cfg.frontend == "vision":
        params["vision_adapter"] = L._dense(keys[5], cfg.d_model, cfg.d_model,
                                            dtype)
    return params


# --------------------------------------------------------------------------
# Blocks
# --------------------------------------------------------------------------

def _apply_layer(p, cfg: ModelConfig, spec: LayerSpec, x, positions,
                 cross_p=None, enc_out=None):
    aux = jnp.zeros((2,), jnp.float32)  # (load_balance, dropped_frac)
    h = L.apply_norm(p["norm1"], x, cfg.norm)
    if spec.mixer == "attn":
        x = x + L.attention_block(p["attn"], cfg, h, positions=positions)
    else:
        x = x + M.apply_mamba(p["mamba"], cfg, h)
    if cross_p is not None:
        hc = L.apply_norm(cross_p["norm_x"], x, cfg.norm)
        x = x + L.attention_block(cross_p["cross"], cfg, hc, causal=False,
                                  kv_input=enc_out)
    if spec.ffn == "dense":
        h = L.apply_norm(p["norm2"], x, cfg.norm)
        x = x + L.apply_mlp(p["mlp"], cfg, h)
    elif spec.ffn == "moe":
        h = L.apply_norm(p["norm2"], x, cfg.norm)
        out, moe_aux = MOE.apply_moe(p["moe"], cfg, h)
        x = x + out
        aux = aux + jnp.stack([moe_aux["load_balance"],
                               moe_aux["dropped_frac"]])
    return shard_hint(x, "act_btd"), aux


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


# --------------------------------------------------------------------------
# Forward (train / prefill)
# --------------------------------------------------------------------------

def forward(params, cfg: ModelConfig, tokens, *, extra: Optional[dict] = None,
            remat: str = "full", return_hidden: bool = False):
    """tokens (B, S_text) int32.  extra carries frontend embeddings / enc in.

    Returns (logits (B, S, padded_vocab), aux (2,)) — or the final hidden
    states instead of logits when ``return_hidden`` (the fused chunked loss
    and last-token-only prefill paths never materialize full logits).
    """
    prefix, period, n_super = plan_layers(cfg)
    x = L.embed(params["embed"], tokens)
    extra = extra or {}

    if cfg.frontend == "vision" and "patches" in extra:
        vis = extra["patches"].astype(x.dtype) @ params["vision_adapter"].astype(x.dtype)
        x = jnp.concatenate([vis, x], axis=1)
    S = x.shape[1]
    positions = jnp.arange(S)
    x = shard_hint(x, "act_btd")

    enc_out = None
    if cfg.family == "encdec":
        enc_out = _encode(params, cfg, extra["frames"], remat=remat)
        x = x + _sinusoid(S, cfg.d_model, x.dtype)

    aux = jnp.zeros((2,), jnp.float32)
    for i, spec in enumerate(prefix):
        x, a = _apply_layer(params["prefix"][i], cfg, spec, x, positions)
        aux = aux + a

    if n_super:
        cross = params.get("cross_blocks")

        def body(carry, blk):
            x, aux = carry
            if cross is not None:
                blk, cb = blk
            for j, spec in enumerate(period):
                cp = cb if (cross is not None and j == 0) else None
                x, a = _apply_layer(blk[f"l{j}"], cfg, spec, x, positions,
                                    cross_p=cp, enc_out=enc_out)
                aux = aux + a
            # barrier: stops XLA hoisting dtype-converts of the remat-saved
            # carry into the residual stack (observed 2x activation HBM)
            x = _opt_barrier(x)
            return (x, aux), None

        xs = (params["blocks"], cross) if cross is not None else params["blocks"]
        (x, aux), _ = jax.lax.scan(_remat(body, remat), (x, aux), xs)

    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    if return_hidden:
        return x, aux
    logits = L.unembed(params["embed"], x)
    return shard_hint(logits, "logits"), aux


def _encode(params, cfg: ModelConfig, frames, *, remat="full"):
    """Whisper encoder over precomputed frame embeddings (frontend stub)."""
    x = frames + _sinusoid(frames.shape[1], cfg.d_model, frames.dtype)
    x = shard_hint(x, "act_btd")

    def body(x, blk):
        h = L.apply_norm(blk["norm1"], x, cfg.norm)
        x = x + L.attention_block(blk["attn"], cfg, h, causal=False)
        h = L.apply_norm(blk["norm2"], x, cfg.norm)
        x = x + L.apply_mlp(blk["mlp"], cfg, h)
        return shard_hint(x, "act_btd"), None

    x, _ = jax.lax.scan(_remat(body, remat), x, params["enc_blocks"])
    return L.apply_norm(params["enc_final_norm"], x, cfg.norm)


@functools.lru_cache(maxsize=8)
def _sinusoid_np(S: int, d: int):
    import numpy as np
    pos = np.arange(S)[:, None]
    dim = np.arange(0, d, 2)[None, :] / d
    ang = pos / (10000 ** dim)
    out = np.zeros((S, d), np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang)
    return out


def _sinusoid(S, d, dtype):
    return jnp.asarray(_sinusoid_np(S, d), dtype)[None]


# --------------------------------------------------------------------------
# Loss
# --------------------------------------------------------------------------

def lm_loss(params, cfg: ModelConfig, tokens, labels, *,
            extra: Optional[dict] = None, remat: str = "full",
            moe_loss_weight: float = 0.01, xent_chunk: int = 8192):
    """Fused chunked softmax-xent: the (T, vocab) logits are never
    materialized — unembed + logsumexp run per token-chunk under remat.
    """
    hidden, aux = forward(params, cfg, tokens, extra=extra, remat=remat,
                          return_hidden=True)
    S_text = labels.shape[1]
    hidden = hidden[:, -S_text:]
    B, S, d = hidden.shape
    T = B * S
    w = params["embed"].get("out")
    transpose = w is None
    if transpose:
        w = params["embed"]["tok"]                  # (V, d), tied
    # pin the loss-entry layout: tokens over dp, d over model — one reshard
    # here instead of one gather per xent chunk when the trunk used
    # sequence-parallel activations
    x = shard_hint(hidden.reshape(T, d), "xent_in")
    y = labels.reshape(T)
    chunk = min(xent_chunk, T)
    if T % chunk:
        chunk = T
    n = T // chunk

    def body(nll_sum, i):
        xc = jax.lax.dynamic_slice_in_dim(x, i * chunk, chunk)
        yc = jax.lax.dynamic_slice_in_dim(y, i * chunk, chunk)
        # tied path contracts via dot_general (td,vd->tv): never materializes
        # the transposed (d,V) embedding per chunk step
        lg = (jnp.einsum("td,vd->tv", xc, w.astype(xc.dtype)) if transpose
              else xc @ w.astype(xc.dtype)).astype(jnp.float32)
        lse = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, yc[:, None], axis=-1)[:, 0]
        return nll_sum + jnp.sum(lse - gold), None

    nll_sum, _ = jax.lax.scan(jax.checkpoint(body), jnp.float32(0.0),
                              jnp.arange(n))
    nll = nll_sum / T
    loss = nll + moe_loss_weight * aux[0]
    return loss, {"nll": nll, "load_balance": aux[0], "dropped_frac": aux[1]}


# --------------------------------------------------------------------------
# Decode (single token, cached)
# --------------------------------------------------------------------------

def _layer_cache(cfg: ModelConfig, spec: LayerSpec, batch, max_seq, dtype):
    if spec.mixer == "mamba":
        return M.init_mamba_state(cfg, batch, dtype)
    W = cfg.sliding_window or 0
    S = min(max_seq, W) if W else max_seq
    return {"k": jnp.zeros((batch, S, cfg.n_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((batch, S, cfg.n_kv_heads, cfg.head_dim), dtype)}


def init_cache(cfg: ModelConfig, batch, max_seq, dtype=jnp.bfloat16,
               enc_out=None, params=None):
    """Decode cache pytree; layers stacked to mirror the scan structure."""
    prefix, period, n_super = plan_layers(cfg)
    cache: dict[str, Any] = {
        "prefix": [_layer_cache(cfg, s, batch, max_seq, dtype) for s in prefix],
        "blocks": _stack([
            {f"l{j}": _layer_cache(cfg, s, batch, max_seq, dtype)
             for j, s in enumerate(period)}
            for _ in range(n_super)]) if n_super else {},
    }
    if cfg.family == "encdec":
        assert enc_out is not None and params is not None
        crosses = []
        n = params["cross_blocks"]["cross"]["wk"].shape[0]
        for i in range(n):
            cp = jax.tree_util.tree_map(lambda a, i=i: a[i],
                                        params["cross_blocks"])
            _, ck, cv = L.qkv_proj(cp["cross"], cfg, enc_out)
            crosses.append({"ck": ck, "cv": cv})
        cache["cross"] = _stack(crosses)
    return cache


def _decode_layer(p, cfg: ModelConfig, spec: LayerSpec, lcache, x, pos,
                  cross_p=None, ccache=None):
    h = L.apply_norm(p["norm1"], x, cfg.norm)
    if spec.mixer == "attn":
        W = cfg.sliding_window
        slot = jnp.mod(pos, W) if W else pos
        k_new, v_new = L.project_kv_token(p["attn"], cfg, h, pos)
        ck = jax.lax.dynamic_update_slice_in_dim(lcache["k"], k_new, slot, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(lcache["v"], v_new, slot, 1)
        lcache = {"k": ck, "v": cv}
        if W:
            # ring buffer: every slot < min(pos+1, W) is live; RoPE was applied
            # at write time so order inside the ring is irrelevant.  The query
            # still ropes at the ABSOLUTE position; `lengths` only masks.
            n_valid = jnp.minimum(pos + 1, W)
            lengths = jnp.full((x.shape[0],), n_valid - 1)
            x = x + L.decode_attention(p["attn"], cfg, h, ck, cv,
                                       pos, lengths=lengths)
        else:
            x = x + L.decode_attention(p["attn"], cfg, h, ck, cv, pos)
    else:
        lcache, out = M.decode_mamba(p["mamba"], cfg, lcache, h)
        x = x + out
    if cross_p is not None:
        hc = L.apply_norm(cross_p["norm_x"], x, cfg.norm)
        from repro.kernels import ops
        B = hc.shape[0]
        q = hc @ cross_p["cross"]["wq"].astype(hc.dtype)
        if "bq" in cross_p["cross"]:
            q = q + cross_p["cross"]["bq"].astype(hc.dtype)
        q = q.reshape(B, 1, cfg.n_heads, cfg.head_dim)
        o = ops.decode_attention(q, ccache["ck"], ccache["cv"],
                                 ccache["ck"].shape[1] - 1)
        o = o.reshape(B, 1, cfg.n_heads * cfg.head_dim)
        x = x + o @ cross_p["cross"]["wo"].astype(hc.dtype)
    if spec.ffn == "dense":
        h = L.apply_norm(p["norm2"], x, cfg.norm)
        x = x + L.apply_mlp(p["mlp"], cfg, h)
    elif spec.ffn == "moe":
        h = L.apply_norm(p["norm2"], x, cfg.norm)
        out, _ = MOE.apply_moe(p["moe"], cfg, h)
        x = x + out
    return lcache, x


def decode_step(params, cfg: ModelConfig, cache, token, pos):
    """token (B,1) int32; pos scalar int32 (absolute position of token).

    Returns (logits (B,1,V), new_cache).
    """
    prefix, period, n_super = plan_layers(cfg)
    x = L.embed(params["embed"], token)
    if cfg.family == "encdec":
        x = x + _sinusoid_at(pos, cfg.d_model, x.dtype)
    x = shard_hint(x, "act_btd_decode")

    new_prefix = []
    for i, spec in enumerate(prefix):
        lc, x = _decode_layer(params["prefix"][i], cfg, spec,
                              cache["prefix"][i], x, pos)
        new_prefix.append(lc)

    new_blocks = cache["blocks"]
    if n_super:
        cross = params.get("cross_blocks")

        def body(x, scanned):
            if cross is not None:
                blk, bc, cp, cc = scanned
            else:
                blk, bc = scanned
            new_bc = {}
            for j, spec in enumerate(period):
                use_cross = cross is not None and j == 0
                new_bc[f"l{j}"], x = _decode_layer(
                    blk[f"l{j}"], cfg, spec, bc[f"l{j}"], x, pos,
                    cross_p=cp if use_cross else None,
                    ccache=cc if use_cross else None)
            return x, new_bc

        if cross is not None:
            xs = (params["blocks"], cache["blocks"], cross, cache["cross"])
        else:
            xs = (params["blocks"], cache["blocks"])
        x, new_blocks = jax.lax.scan(body, x, xs)

    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = L.unembed(params["embed"], x)
    new_cache = dict(cache)
    new_cache["prefix"] = new_prefix
    new_cache["blocks"] = new_blocks
    return logits, new_cache


def _sinusoid_at(pos, d, dtype):
    i = jnp.arange(0, d, 2) / d
    ang = pos.astype(jnp.float32) / (10000.0 ** i)
    out = jnp.zeros((d,), jnp.float32)
    out = out.at[0::2].set(jnp.sin(ang)).at[1::2].set(jnp.cos(ang))
    return out.astype(dtype)[None, None]
