"""Core transformer layers: norms, RoPE, GQA attention, MLPs.

Pure-functional style: ``init_*`` returns a param pytree, ``apply``-style
functions consume it.  All attention paths route through
:func:`repro.kernels.ops.attention`, which dispatches to the Pallas kernel on
TPU and a chunked-jnp flash equivalent elsewhere (memory-safe at 32k+).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, dtype=jnp.float32):
    if cfg.norm == "rms":
        return {"scale": jnp.ones((cfg.d_model,), dtype)}
    if cfg.norm == "layer":
        return {"scale": jnp.ones((cfg.d_model,), dtype),
                "bias": jnp.zeros((cfg.d_model,), dtype)}
    if cfg.norm == "nonparam":
        return {}
    raise ValueError(cfg.norm)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _rms_core(x, scale, eps):
    y, _ = _rms_fwd(x, scale, eps)
    return y


def _rms_stats(x, eps):
    d = x.shape[-1]
    ms = jnp.einsum("...d,...d->...", x, x,
                    preferred_element_type=jnp.float32)[..., None] / d
    return jax.lax.rsqrt(ms + eps)                      # (..., 1) f32


def _rms_fwd(x, scale, eps):
    inv = _rms_stats(x, eps)
    y = x * inv.astype(x.dtype) * scale.astype(x.dtype)
    return y, (x, scale, inv)


def _rms_bwd(eps, res, dy):
    """All full-width tensors stay in x.dtype; only (...,1) stats are f32.

    A plain-autodiff RMSNorm upcasts x to f32 in the backward, which XLA then
    hoists into the remat-saved layer residuals — doubling activation HBM on
    the 512-device dry-run.  This custom VJP removes the f32 path entirely.
    """
    x, scale, inv = res
    d = x.shape[-1]
    dt = x.dtype
    g = dy * scale.astype(dt)
    dot = jnp.einsum("...d,...d->...", g, x,
                     preferred_element_type=jnp.float32)[..., None]
    coef = (inv ** 3) * (dot / d)
    dx = g * inv.astype(dt) - x * coef.astype(dt)
    dscale = jnp.einsum("...d,...d->d", dy, x * inv.astype(dt),
                        preferred_element_type=jnp.float32)
    return dx, dscale.astype(scale.dtype)


_rms_core.defvjp(_rms_fwd, _rms_bwd)


def apply_norm(params, x, kind: str, eps: float = 1e-5):
    """Statistics accumulate in f32; full-width tensors stay in x.dtype."""
    dt = x.dtype
    d = x.shape[-1]
    if kind == "rms":
        return _rms_core(x, params["scale"], eps)
    mean = (jnp.sum(x, axis=-1, keepdims=True, dtype=jnp.float32) / d)
    ms = jnp.einsum("...d,...d->...", x, x,
                    preferred_element_type=jnp.float32)[..., None] / d
    var = ms - mean * mean
    inv = jax.lax.rsqrt(var + eps)
    out = (x - mean.astype(dt)) * inv.astype(dt)
    if kind == "layer":
        out = out * params["scale"].astype(dt) + params["bias"].astype(dt)
    return out


# --------------------------------------------------------------------------
# Rotary position embeddings
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float, positions: jnp.ndarray):
    """(..., head_dim//2) cos/sin tables for given positions."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    ang = positions.astype(jnp.float32)[..., None] * inv  # (..., hd/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray):
    """x: (B, S, H, D); cos/sin: (S, D/2) or (B, S, D/2)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    if cos.ndim == 2:  # (S, D/2) -> broadcast over batch and heads
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:              # (B, S, D/2)
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(dt)


# --------------------------------------------------------------------------
# Linear / embedding initializers
# --------------------------------------------------------------------------

def _dense(key, d_in, d_out, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return jax.random.normal(key, (d_in, d_out), dtype) * jnp.asarray(scale, dtype)


def init_embedding(key, cfg: ModelConfig, dtype=jnp.float32):
    p = {"tok": jax.random.normal(key, (cfg.padded_vocab, cfg.d_model), dtype) * 0.02}
    if not cfg.tie_embeddings:
        p["out"] = _dense(jax.random.fold_in(key, 1), cfg.d_model,
                          cfg.padded_vocab, dtype)
    return p


def embed(params, tokens):
    return params["tok"][tokens]


def unembed(params, x):
    w = params.get("out")
    if w is None:
        w = params["tok"].T
    return x @ w.astype(x.dtype)


# --------------------------------------------------------------------------
# Attention (GQA, optional bias / sliding window / cross-attention)
# --------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, dtype=jnp.float32):
    d, hd, nq, nkv = cfg.d_model, cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense(ks[0], d, nq * hd, dtype),
        "wk": _dense(ks[1], d, nkv * hd, dtype),
        "wv": _dense(ks[2], d, nkv * hd, dtype),
        "wo": _dense(ks[3], nq * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nq * hd,), dtype)
        p["bk"] = jnp.zeros((nkv * hd,), dtype)
        p["bv"] = jnp.zeros((nkv * hd,), dtype)
    return p


def qkv_proj(params, cfg: ModelConfig, x, kv_input=None):
    """Project to (q, k, v) with shapes (B, S, n, hd)."""
    B, S, _ = x.shape
    kv_input = x if kv_input is None else kv_input
    Skv = kv_input.shape[1]
    q = x @ params["wq"].astype(x.dtype)
    k = kv_input @ params["wk"].astype(x.dtype)
    v = kv_input @ params["wv"].astype(x.dtype)
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    from repro.parallel.ctx import shard_hint
    q = shard_hint(q.reshape(B, S, cfg.n_heads, cfg.head_dim), "act_q")
    k = shard_hint(k.reshape(B, Skv, cfg.n_kv_heads, cfg.head_dim), "act_kv")
    v = shard_hint(v.reshape(B, Skv, cfg.n_kv_heads, cfg.head_dim), "act_kv")
    return q, k, v


def attention_block(params, cfg: ModelConfig, x, *, positions=None,
                    causal=True, kv_input=None, kv_positions=None):
    """Full attention sub-layer (projections + core attention + out proj)."""
    from repro.kernels import ops  # local import: kernels may pick backend lazily

    B, S, _ = x.shape
    q, k, v = qkv_proj(params, cfg, x, kv_input)
    if cfg.rope_theta:
        if positions is None:
            positions = jnp.arange(S)
        cos, sin = rope_freqs(cfg.head_dim, cfg.rope_theta, positions)
        q = apply_rope(q, cos, sin)
        if kv_input is None:
            k = apply_rope(k, cos, sin)
        else:
            kvp = kv_positions if kv_positions is not None else jnp.arange(k.shape[1])
            ck, sk = rope_freqs(cfg.head_dim, cfg.rope_theta, kvp)
            k = apply_rope(k, ck, sk)
    out = ops.attention(q, k, v, causal=causal and kv_input is None,
                        sliding_window=cfg.sliding_window)
    out = out.reshape(B, S, cfg.n_heads * cfg.head_dim)
    return out @ params["wo"].astype(x.dtype)


def decode_attention(params, cfg: ModelConfig, x, cache_k, cache_v, pos,
                     *, lengths=None):
    """Single-token decode: x (B, 1, d); cache_{k,v} (B, S, nkv, hd).

    ``pos`` is the absolute position of the new token; the caller has already
    placed the new k/v into the cache (see model.py) so attention runs over
    cache[0:pos+1].  Returns (B, 1, d).
    """
    from repro.kernels import ops

    B = x.shape[0]
    q = x @ params["wq"].astype(x.dtype)
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype)
    q = q.reshape(B, 1, cfg.n_heads, cfg.head_dim)
    if cfg.rope_theta:
        cos, sin = rope_freqs(cfg.head_dim, cfg.rope_theta, pos[None])
        q = apply_rope(q, cos, sin)
    out = ops.decode_attention(q, cache_k, cache_v, pos, lengths=lengths)
    return out.reshape(B, 1, cfg.n_heads * cfg.head_dim) @ params["wo"].astype(x.dtype)


def project_kv_token(params, cfg: ModelConfig, x, pos):
    """Project one token's k/v (for cache insertion), with RoPE at ``pos``."""
    B = x.shape[0]
    k = x @ params["wk"].astype(x.dtype)
    v = x @ params["wv"].astype(x.dtype)
    if "bk" in params:
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    k = k.reshape(B, 1, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, 1, cfg.n_kv_heads, cfg.head_dim)
    if cfg.rope_theta:
        cos, sin = rope_freqs(cfg.head_dim, cfg.rope_theta, pos[None])
        k = apply_rope(k, cos, sin)
    return k, v


# --------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# --------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: int, dtype=jnp.float32):
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    if cfg.act == "swiglu":
        return {"wg": _dense(ks[0], d, d_ff, dtype),
                "wu": _dense(ks[1], d, d_ff, dtype),
                "wd": _dense(ks[2], d_ff, d, dtype)}
    return {"wu": _dense(ks[0], d, d_ff, dtype),
            "wd": _dense(ks[1], d_ff, d, dtype)}


def apply_mlp(params, cfg: ModelConfig, x):
    from repro.parallel.ctx import shard_hint
    if cfg.act == "swiglu":
        g = x @ params["wg"].astype(x.dtype)
        u = x @ params["wu"].astype(x.dtype)
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(x @ params["wu"].astype(x.dtype))
    if h.ndim == 3:
        h = shard_hint(h, "act_btf")     # keep FFN hidden tensor-parallel
    return h @ params["wd"].astype(x.dtype)
