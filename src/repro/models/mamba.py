"""Mamba-1 block (selective SSM) — falcon-mamba / jamba mixer.

Block: in_proj -> (x, z); depthwise causal conv1d + SiLU on x; selection
projections (dt, B, C); selective scan (repro.kernels.ops); gate by SiLU(z);
out_proj.  Decode keeps an O(1) state: (conv tail, SSM state h).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    dt_rank = s.dt_rank or -(-cfg.d_model // 16)
    return d_in, dt_rank, s.d_state, s.d_conv


def init_mamba(key, cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    d_in, dt_rank, N, d_conv = _dims(cfg)
    ks = jax.random.split(key, 7)
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype))
    return {
        # separate x/z projections (a fused (d, 2*d_in) would split across
        # the TP shards after the matmul — see parallel/sharding.py)
        "in_x": jax.random.normal(ks[0], (d, d_in), dtype) * scale,
        "in_z": jax.random.normal(ks[6], (d, d_in), dtype) * scale,
        "conv_w": jax.random.normal(ks[1], (d_conv, d_in), dtype) * 0.2,
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": jax.random.normal(ks[2], (d_in, dt_rank + 2 * N), dtype)
                  * (1.0 / jnp.sqrt(jnp.asarray(d_in, dtype))),
        "dt_proj": jax.random.normal(ks[3], (dt_rank, d_in), dtype)
                   * (1.0 / jnp.sqrt(jnp.asarray(dt_rank, dtype))),
        "dt_bias": jnp.log(jnp.expm1(  # softplus^-1 of uniform [1e-3, 1e-1]
            10 ** jax.random.uniform(ks[4], (d_in,), jnp.float32,
                                     -3.0, -1.0))).astype(dtype),
        "A_log": jnp.log(jnp.tile(
            jnp.arange(1, N + 1, dtype=jnp.float32), (d_in, 1))).astype(dtype),
        "D": jnp.ones((d_in,), dtype),
        "out_proj": jax.random.normal(ks[5], (d_in, d), dtype)
                    * (1.0 / jnp.sqrt(jnp.asarray(d_in, dtype))),
    }


def _selection(params, cfg, xc):
    """xc (B,S,d_in) -> dt (B,S,d_in), Bc (B,S,N), Cc (B,S,N)."""
    _, dt_rank, N, _ = _dims(cfg)
    sel = xc @ params["x_proj"].astype(xc.dtype)
    dt_r, Bc, Cc = jnp.split(sel, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(
        dt_r @ params["dt_proj"].astype(xc.dtype)
        + params["dt_bias"].astype(xc.dtype))
    return dt, Bc, Cc


def apply_mamba(params, cfg: ModelConfig, x):
    """Full-sequence forward: x (B,S,d) -> (B,S,d)."""
    B, S, _ = x.shape
    d_in, dt_rank, N, d_conv = _dims(cfg)
    xc = x @ params["in_x"].astype(x.dtype)               # (B,S,d_in)
    z = x @ params["in_z"].astype(x.dtype)
    # depthwise causal conv1d along S
    xpad = jnp.pad(xc, ((0, 0), (d_conv - 1, 0), (0, 0)))
    w = params["conv_w"].astype(x.dtype)                  # (d_conv, d_in)
    xc = sum(xpad[:, i:i + S] * w[i][None, None] for i in range(d_conv))
    xc = jax.nn.silu(xc + params["conv_b"].astype(x.dtype))
    dt, Bc, Cc = _selection(params, cfg, xc)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))     # (d_in, N)
    y = ops.selective_scan(xc, dt, A, Bc, Cc, params["D"])
    y = y * jax.nn.silu(z)
    return y @ params["out_proj"].astype(x.dtype)


def init_mamba_state(cfg: ModelConfig, batch, dtype=jnp.float32):
    d_in, _, N, d_conv = _dims(cfg)
    return {"conv": jnp.zeros((batch, d_conv - 1, d_in), dtype),
            "h": jnp.zeros((batch, d_in, N), jnp.float32)}


def decode_mamba(params, cfg: ModelConfig, state, x):
    """One decode step: x (B,1,d), O(1) state update."""
    B = x.shape[0]
    d_in, dt_rank, N, d_conv = _dims(cfg)
    xc = x[:, 0] @ params["in_x"].astype(x.dtype)         # (B, d_in)
    z = x[:, 0] @ params["in_z"].astype(x.dtype)
    # conv over [state.conv ; xc]
    hist = jnp.concatenate([state["conv"], xc[:, None]], axis=1)  # (B,d_conv,d_in)
    w = params["conv_w"].astype(x.dtype)
    xconv = (hist * w[None]).sum(axis=1) + params["conv_b"].astype(x.dtype)
    xconv = jax.nn.silu(xconv)
    dt, Bc, Cc = _selection(params, cfg, xconv[:, None])
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    h, y = ops.ssm_decode(state["h"], xconv, dt[:, 0], A, Bc[:, 0], Cc[:, 0],
                          params["D"])
    y = y * jax.nn.silu(z)
    out = (y @ params["out_proj"].astype(x.dtype))[:, None]
    new_state = {"conv": hist[:, 1:], "h": h}
    return new_state, out
