"""Pluggable mapping/beacon policy core (paper Sec 4, generalized).

The paper evaluates exactly one management strategy: stage-1 cluster
choice by min-search over (possibly stale) beacon views, and
threshold-based status communication.  This module widens both decisions
into first-class, *sweepable* design-space axes (ROADMAP north star;
cf. Myrmics' hierarchical ownership scheduling, arXiv:1606.04282, and the
decision-quality vs. manager-traffic trade of arXiv:2009.03066):

  MappingPolicy  stage-1 cluster choice given a possibly-stale view
                 ``min_search``          the paper's rule: min-search over the
                                         view, ties broken starting at the
                                         deciding GMN's own index
                 ``round_robin``         ignore the view; cycle clusters
                                         starting at the own index (one
                                         persistent pointer per GMN)
                 ``hashed_random``       stateless uint32 hash of
                                         (app, decision-index, gmn) — the
                                         "power of zero choices" baseline
                 ``staleness_weighted``  min-search over view + age/T_b: a
                                         cluster whose beacon is stale is
                                         assumed to have drifted busier

  BeaconPolicy   status-communication trigger for a GMN whose summarized
                 load is ``last_bcast + delta``
                 ``threshold``  fire when |delta| >= dn_th (paper Sec 4.2)
                 ``periodic``   fire when t - last_tx >= T_b, regardless
                                of drift
                 ``hybrid``     threshold OR deadline: drift fires early,
                                the T_b deadline bounds silent staleness

Every policy exists in two bitwise-matching forms:

- a **traced** JAX function (``mapping_policy(name)`` /
  ``beacon_policy(name)``) used inside the TLM simulator's event handlers
  — pure jnp, vmap-safe, no host syncs; and
- a **host** numpy adapter (``host_pick`` / ``host_stage2`` /
  ``host_beacon_due``) used by the wall-clock layers
  (``serving.engine``, ``core.beacons``, ``core.mapping``), which must
  decide per-request without entering a trace.

The policy *name* is static — ``SimPolicy`` is a hashable frozen
dataclass passed as a static JIT argument, so each (mapping, beacon)
combination is one XLA program — while the numeric parameters
(``dn_th``, ``T_b``) stay traced ``SimKnobs`` leaves and remain
vmap-sweepable (DESIGN.md §9).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

MAPPING_POLICIES = ("min_search", "round_robin", "hashed_random",
                    "staleness_weighted")
BEACON_POLICIES = ("threshold", "periodic", "hybrid")


@dataclass(frozen=True)
class SimPolicy:
    """Static policy selection: hashable, one XLA program per value."""
    mapping: str = "min_search"
    beacon: str = "threshold"

    def __post_init__(self):
        if self.mapping not in MAPPING_POLICIES:
            raise ValueError(f"unknown mapping policy {self.mapping!r}; "
                             f"choose from {MAPPING_POLICIES}")
        if self.beacon not in BEACON_POLICIES:
            raise ValueError(f"unknown beacon policy {self.beacon!r}; "
                             f"choose from {BEACON_POLICIES}")


DEFAULT_POLICY = SimPolicy()


def policy_grid(mappings=MAPPING_POLICIES, beacons=BEACON_POLICIES):
    """All (mapping x beacon) combinations as SimPolicy values,
    row-major (mapping outermost)."""
    return [SimPolicy(m, b) for m in mappings for b in beacons]


# ==========================================================================
# Traced mapping policies (used by repro.core.sim inside the event loop)
#
# Common signature:  fn(view, age, g, rr, app, i, *, k, T_b) -> cluster i32
#   view (k,) i32   per-cluster load summaries, own entry exact
#   age  (k,) f32   ticks since each summary was received (own entry 0)
#   g        i32    the deciding GMN's index
#   rr       i32    the GMN's persistent decision counter (round-robin ptr)
#   app, i   i32    application id / decision index within this fork
#   k        int    static cluster count;  T_b  traced f32 beacon period
# ==========================================================================

def _own_first(k, g):
    """Search order starting at the deciding GMN's own index (models the
    hardware min-search starting at the local node, DESIGN.md §6)."""
    return jnp.mod(jnp.arange(k) + g, k)


def _map_min_search(view, age, g, rr, app, i, *, k, T_b):
    perm = _own_first(k, g)
    return perm[jnp.argmin(view[perm])]


def _map_round_robin(view, age, g, rr, app, i, *, k, T_b):
    return jnp.mod(g + rr, k).astype(jnp.int32)


def _map_hashed_random(view, age, g, rr, app, i, *, k, T_b):
    h = _hash_u32(jnp.asarray(app), jnp.asarray(i), jnp.asarray(g))
    return jnp.mod(h, jnp.uint32(k)).astype(jnp.int32)


def _map_staleness_weighted(view, age, g, rr, app, i, *, k, T_b):
    # A summary that is `age` ticks old is presumed one load-unit busier
    # per elapsed beacon period: score = view + age / T_b.
    score = view.astype(jnp.float32) \
        + age / jnp.maximum(T_b, jnp.float32(1.0))
    perm = _own_first(k, g)
    return perm[jnp.argmin(score[perm])]


_MAPPING = {
    "min_search": _map_min_search,
    "round_robin": _map_round_robin,
    "hashed_random": _map_hashed_random,
    "staleness_weighted": _map_staleness_weighted,
}


def mapping_policy(name: str):
    try:
        return _MAPPING[name]
    except KeyError:
        raise ValueError(f"unknown mapping policy {name!r}; "
                         f"choose from {MAPPING_POLICIES}") from None


# ==========================================================================
# Traced beacon policies
#
# Common signature:  fn(delta, t, last_tx, *, dn_th, T_b) -> fire bool
#   delta   i32/f32  |current summarized load - last broadcast value|
#   t       f32      current tick;  last_tx f32 last transmission grant
#   dn_th   i32      traced drift threshold;  T_b f32 traced period
# (the k > 1 gate — a single cluster never broadcasts — stays in the
# caller, it is topology not policy)
# ==========================================================================

def _bc_threshold(delta, t, last_tx, *, dn_th, T_b):
    return delta >= dn_th


def _bc_periodic(delta, t, last_tx, *, dn_th, T_b):
    return (t - last_tx) >= T_b


def _bc_hybrid(delta, t, last_tx, *, dn_th, T_b):
    return jnp.logical_or(delta >= dn_th, (t - last_tx) >= T_b)


_BEACON = {
    "threshold": _bc_threshold,
    "periodic": _bc_periodic,
    "hybrid": _bc_hybrid,
}


def beacon_policy(name: str):
    try:
        return _BEACON[name]
    except KeyError:
        raise ValueError(f"unknown beacon policy {name!r}; "
                         f"choose from {BEACON_POLICIES}") from None


# ==========================================================================
# uint32 mixing hash — identical bits in both the traced and host form
# ==========================================================================

_H1, _H2, _H3, _H4 = 0x9E3779B1, 0x85EBCA77, 0xC2B2AE3D, 0x2C1B3C6D
_M32 = 0xFFFFFFFF


def _hash_u32(a, b, c):
    """Traced xor-multiply mix of three int scalars -> uint32."""
    h = (a.astype(jnp.uint32) * jnp.uint32(_H1)
         ^ b.astype(jnp.uint32) * jnp.uint32(_H2)
         ^ c.astype(jnp.uint32) * jnp.uint32(_H3))
    h = h ^ (h >> 15)
    h = h * jnp.uint32(_H4)
    return h ^ (h >> 12)


def _hash_u32_host(a: int, b: int, c: int) -> int:
    """Python-int twin of :func:`_hash_u32` (same bits, no tracing)."""
    h = ((a * _H1) & _M32) ^ ((b * _H2) & _M32) ^ ((c * _H3) & _M32)
    h ^= h >> 15
    h = (h * _H4) & _M32
    return h ^ (h >> 12)


# ==========================================================================
# Host (wall-clock numpy) adapters — serving.engine / core.beacons /
# core.mapping delegate here so the decision logic exists exactly once.
# ==========================================================================

def host_pick(name: str, view, age=None, own: int = 0, rr: int = 0,
              salt: int = 0, i: int = 0, *, T_b: float = float("inf")) -> int:
    """Stage-1 cluster choice in the wall-clock domain.

    view (k,) load summaries (own entry exact); age (k,) seconds since
    each summary was received (None = all fresh); own/rr/salt/i mirror
    the traced g/rr/app/i arguments.
    """
    view = np.asarray(view, np.float64)
    k = view.shape[0]
    if name == "round_robin":
        return int((own + rr) % k)
    if name == "hashed_random":
        return int(_hash_u32_host(int(salt), int(i), int(own)) % k)
    perm = (np.arange(k) + own) % k
    if name == "staleness_weighted":
        # score in float32 like the traced form: f64 here would resolve
        # near-ties differently and break the bitwise-matching contract
        a = np.zeros(k, np.float32) if age is None \
            else np.asarray(age, np.float32)
        view = view.astype(np.float32) \
            + a / np.float32(max(float(T_b), 1.0))
    elif name != "min_search":
        raise ValueError(f"unknown mapping policy {name!r}; "
                         f"choose from {MAPPING_POLICIES}")
    return int(perm[int(np.argmin(view[perm]))])


def host_stage2(loads, alive=None) -> int:
    """Stage-2 unit choice: argmin over the exact local load table,
    dead units masked out."""
    loads = np.asarray(loads, np.float64)
    if alive is not None:
        loads = np.where(np.asarray(alive, bool), loads, np.inf)
    return int(np.argmin(loads))


def host_beacon_due(name: str, delta, now: float = 0.0,
                    last_tx: float = 0.0, *, dn_th,
                    T_b: float = float("inf")) -> bool:
    """Status-communication trigger in the wall-clock domain (the k > 1
    gate stays with the caller)."""
    if name == "threshold":
        return bool(abs(delta) >= dn_th)
    if name == "periodic":
        return bool((now - last_tx) >= T_b)
    if name == "hybrid":
        return bool(abs(delta) >= dn_th or (now - last_tx) >= T_b)
    raise ValueError(f"unknown beacon policy {name!r}; "
                     f"choose from {BEACON_POLICIES}")
