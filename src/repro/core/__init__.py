"""Paper core: messages, analytic model, two-stage mapping, beacons, TLM sim."""
