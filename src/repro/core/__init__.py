"""Paper core: messages, analytic model, pluggable mapping/beacon
policies, interconnect transport topologies, two-stage mapping, beacons,
TLM sim, batched sweeps."""
