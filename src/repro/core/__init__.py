"""Paper core: messages, analytic model, pluggable mapping/beacon
policies, two-stage mapping, beacons, TLM sim, batched sweeps."""
