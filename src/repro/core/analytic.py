"""Analytic overhead model — paper Eqns (1)-(4) + Fig 2a generator.

S(m, n, k) = n*l / (ceil(n/m)*l + Omega(m, n, k))
Omega      = Omega_cmp + Omega_msg
Omega_cmp  = log(n) * Omega_s(k)  +  (n/k) * Omega_s(m/k)
Omega_msg  = c_b * k + c_b * (m/k)
Omega_s(v) = c_s * log2(v)        (RB-tree min-search)
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class TimingParams:
    """Paper Table 3 defaults (ticks)."""
    c_b: float = 8.0          # message delay: 4 tx + 4 rx
    c_s: float = 8.0          # selection delay coefficient
    task_len: float = 16_000.0
    sim_len: float = 1e7


def omega_s(nu, c_s: float):
    nu = np.asarray(nu, np.float64)
    return c_s * np.log2(np.maximum(nu, 1.0))


def omega_cmp(m, n, k, c_s: float):
    k = np.asarray(k, np.float64)
    return (np.log2(np.maximum(n, 2.0)) * omega_s(k, c_s)
            + (n / k) * omega_s(m / k, c_s))


def omega_msg(m, n, k, c_b: float):
    k = np.asarray(k, np.float64)
    return c_b * k + c_b * (m / k)


def omega(m, n, k, p: TimingParams = TimingParams()):
    return omega_cmp(m, n, k, p.c_s) + omega_msg(m, n, k, p.c_b)


def speedup(m, n, k, p: TimingParams = TimingParams(), l=None):
    l = p.task_len if l is None else l
    t_seq = n * l
    t_par = np.ceil(n / np.asarray(m, np.float64)) * l + omega(m, n, k, p)
    return t_seq / t_par


def optimal_k(m, n, p: TimingParams = TimingParams()):
    ks = np.array([2 ** i for i in range(int(np.log2(m)) + 1)])
    return int(ks[np.argmax(speedup(m, n, ks, p))])


def fig2a(m=256, n=256, c_s_values=(1.0, 8.0, 64.0),
          p: TimingParams = TimingParams()):
    """Projected speedup vs k for several selection-delay coefficients."""
    ks = np.array([2 ** i for i in range(int(np.log2(m)) + 1)])
    out = {}
    for cs in c_s_values:
        pp = TimingParams(c_b=p.c_b, c_s=cs, task_len=p.task_len)
        out[cs] = {"k": ks.tolist(),
                   "speedup": speedup(m, n, ks, pp).tolist()}
    return out
