"""Threshold-based status communication (paper Sec 4.2).

A node broadcasts its summarized load whenever it drifted >= dn_th from the
last broadcast value.  Pure-functional state machine used by the TLM sim
(inlined there for tick accounting) and by the serving engine's cluster
schedulers (wall-clock domain).
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import numpy as np


@dataclass(frozen=True)
class BeaconState:
    k: int
    dn_th: int
    last_bcast: np.ndarray        # (k,) value at last broadcast per node
    view: np.ndarray              # (k, k) view[i, j] = node i's view of j
    tx_count: int = 0

    @classmethod
    def create(cls, k: int, dn_th: int):
        return cls(k=k, dn_th=dn_th,
                   last_bcast=np.zeros(k, np.int64),
                   view=np.zeros((k, k), np.int64))


def update(state: BeaconState, node: int, load: int) -> BeaconState:
    """Node reports its current load; broadcast fires on threshold drift."""
    view = state.view.copy()
    view[node, node] = load                      # own view is always exact
    if abs(int(load) - int(state.last_bcast[node])) >= state.dn_th \
            and state.k > 1:
        last = state.last_bcast.copy()
        last[node] = load
        view[:, node] = load                     # all remotes receive
        return replace(state, view=view, last_bcast=last,
                       tx_count=state.tx_count + 1)
    return replace(state, view=view)


def staleness(state: BeaconState, true_loads: np.ndarray) -> float:
    """Mean |view - truth| over remote entries — the information deficit the
    paper identifies as the cause of mis-mapping (Sec 6)."""
    err = np.abs(state.view - true_loads[None, :]).astype(np.float64)
    off_diag = ~np.eye(state.k, dtype=bool)
    return float(err[off_diag].mean()) if state.k > 1 else 0.0
