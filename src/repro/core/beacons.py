"""Status-communication state machine in the wall-clock domain
(paper Sec 4.2, generalized).

A node reports its summarized load after every load change; whether that
report becomes a broadcast is decided by the selected *beacon policy*
(``repro.core.policies``): ``threshold`` — the paper's rule, broadcast
when the load drifted >= dn_th from the last broadcast value;
``periodic`` — broadcast every T_b time units; ``hybrid`` — threshold
with a T_b deadline.  The TLM simulator implements the same policies in
the tick domain (``core/sim._maybe_beacon``); this pure-functional twin
serves the serving engine's cluster schedulers and host-side analysis.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

import numpy as np

from repro.core import policies as P


@dataclass(frozen=True)
class BeaconState:
    k: int
    dn_th: int
    last_bcast: np.ndarray        # (k,) value at last broadcast per node
    view: np.ndarray              # (k, k) view[i, j] = node i's view of j
    tx_count: int = 0
    policy: str = "threshold"     # beacon policy name (core/policies.py)
    T_b: float = float("inf")     # period/deadline (periodic, hybrid)
    last_tx: np.ndarray = field(default=None)  # (k,) last broadcast time

    def __post_init__(self):
        # direct construction with the pre-policy field set stays valid
        if self.last_tx is None:
            object.__setattr__(self, "last_tx", np.zeros(self.k, np.float64))

    @classmethod
    def create(cls, k: int, dn_th: int, *, policy: str = "threshold",
               T_b: float = float("inf")):
        if policy not in P.BEACON_POLICIES:
            raise ValueError(f"unknown beacon policy {policy!r}; "
                             f"choose from {P.BEACON_POLICIES}")
        return cls(k=k, dn_th=dn_th, policy=policy, T_b=T_b,
                   last_bcast=np.zeros(k, np.int64),
                   view=np.zeros((k, k), np.int64),
                   last_tx=np.zeros(k, np.float64))


def update(state: BeaconState, node: int, load: int,
           now: float = 0.0) -> BeaconState:
    """Node reports its current load; the policy decides whether to
    broadcast (``now`` only matters for the time-based policies)."""
    view = state.view.copy()
    view[node, node] = load                      # own view is always exact
    due = P.host_beacon_due(
        state.policy, int(load) - int(state.last_bcast[node]), now,
        float(state.last_tx[node]), dn_th=state.dn_th, T_b=state.T_b)
    if due and state.k > 1:
        last = state.last_bcast.copy()
        last[node] = load
        last_tx = state.last_tx.copy()
        last_tx[node] = now
        view[:, node] = load                     # all remotes receive
        return replace(state, view=view, last_bcast=last, last_tx=last_tx,
                       tx_count=state.tx_count + 1)
    return replace(state, view=view)


def staleness(state: BeaconState, true_loads: np.ndarray) -> float:
    """Mean |view - truth| over remote entries — the information deficit the
    paper identifies as the cause of mis-mapping (Sec 6).  The threshold
    policy bounds every remote entry's error by dn_th - 1 right after the
    node reported (tests/test_policies.py)."""
    err = np.abs(state.view - true_loads[None, :]).astype(np.float64)
    off_diag = ~np.eye(state.k, dtype=bool)
    return float(err[off_diag].mean()) if state.k > 1 else 0.0
