"""Event-driven transaction-level simulator of the clustered task manager.

Faithful JAX re-implementation of the paper's TLM evaluation (Sec 5):

  entities   k GMNs (serialized mapping compute, c_s per decision level),
             m PEs with FCFS queues, one global bus, k local buses
             (c_b per message, serialized per bus),
  mechanisms two-stage recursive task mapping (Sec 4.1), threshold-based
             status beacons (Sec 4.2, threshold dn_th), join/barrier
             synchronization (Tab 2).

All state lives in fixed-shape arrays; the run is one ``lax.while_loop``
over a bounded event queue, so a full interference experiment jits once and
sweeps (k, dn_th) via vmap-free re-jit per static config.

Event types:
  ARRIVE(app)             application hits its stimulus GMN; the GMN expands
                          the recursive fork tree (stage-1 decisions over its
                          beacon view) and emits LOCAL_SPAWN messages.
  LOCAL_SPAWN(app, g, n)  cluster g maps n child tasks onto its PEs
                          (stage-2 min-search, exact local view), one
                          decision + one local-bus task-start per child.
  JOIN_EXIT(app, g, p)    child finished: local-bus join-exit message,
                          barrier decrement, load decrement, beacon check.

Deviations from the paper (documented in DESIGN.md §8): helper tasks occupy
the management plane (GMN time) rather than PEs; per-receiver beacon skew is
ignored (view updates atomically at bus-grant time).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

INF = jnp.float32(1e18)

EV_ARRIVE = 0
EV_LOCAL_SPAWN = 1
EV_JOIN_EXIT = 2


@dataclass(frozen=True)
class SimParams:
    m: int = 256                 # processing elements
    k: int = 16                  # global management nodes (clusters)
    c_b: float = 8.0             # message delay (4 tx + 4 rx), bus-serialized
    c_s: float = 8.0             # selection delay coefficient
    c_join: float = 8.0          # GMN barrier-decrement processing
    dn_th: int = 4               # beacon threshold
    n_childs: int = 100          # child tasks per application
    queue_cap: int = 2048
    max_apps: int = 512

    @property
    def mpk(self) -> int:
        return self.m // self.k

    @property
    def sel_global(self) -> float:
        """Stage-1 decision cost c_s * log2(k)."""
        return float(self.c_s * np.log2(max(self.k, 2))) if self.k > 1 else 0.0

    @property
    def sel_local(self) -> float:
        """Stage-2 decision cost c_s * log2(m/k)."""
        return float(self.c_s * np.log2(max(self.mpk, 2))) if self.mpk > 1 else 0.0


def make_state(p: SimParams):
    k, mpk, Q, A = p.k, p.mpk, p.queue_cap, p.max_apps
    return {
        # event queue (slot-recycled)
        "ev_time": jnp.full((Q,), INF),
        "ev_type": jnp.zeros((Q,), jnp.int32),
        "ev_a": jnp.zeros((Q, 3), jnp.int32),      # (app, gmn/cluster, pe/cnt)
        # infra
        "pe_free": jnp.zeros((k, mpk), jnp.float32),
        "gmn_free": jnp.zeros((k,), jnp.float32),
        "gbus_free": jnp.zeros((), jnp.float32),
        "lbus_free": jnp.zeros((k,), jnp.float32),
        # load bookkeeping
        "loads": jnp.zeros((k, mpk), jnp.int32),   # mapped tasks per PE
        "view": jnp.zeros((k, k), jnp.int32),      # GMN g's view of cluster c
        "last_bcast": jnp.zeros((k,), jnp.int32),
        "beacons_tx": jnp.zeros((), jnp.int32),
        # applications
        "app_remaining": jnp.zeros((A,), jnp.int32),
        "app_arrive": jnp.full((A,), INF),
        "app_done": jnp.full((A,), INF),
        "events_processed": jnp.zeros((), jnp.int32),
        "dropped": jnp.zeros((), jnp.int32),
    }


def _push(st, t, typ, a0, a1, a2):
    slot = jnp.argmax(st["ev_time"] >= INF)       # first free slot
    ok = st["ev_time"][slot] >= INF
    st = dict(st)
    st["ev_time"] = st["ev_time"].at[slot].set(jnp.where(ok, t, st["ev_time"][slot]))
    st["ev_type"] = st["ev_type"].at[slot].set(jnp.where(ok, typ, st["ev_type"][slot]))
    st["ev_a"] = st["ev_a"].at[slot].set(
        jnp.where(ok, jnp.stack([a0, a1, a2]), st["ev_a"][slot]))
    st["dropped"] = st["dropped"] + jnp.where(ok, 0, 1)
    return st


def _maybe_beacon(st, p: SimParams, g, t):
    """Threshold-based status broadcast (Sec 4.2)."""
    load_g = st["loads"][g].sum()
    delta = jnp.abs(load_g - st["last_bcast"][g])
    fire = jnp.logical_and(delta >= p.dn_th, p.k > 1)
    # bus grant: serialize on the global bus
    t_tx = jnp.maximum(t, st["gbus_free"]) + p.c_b
    st = dict(st)
    st["gbus_free"] = jnp.where(fire, t_tx, st["gbus_free"])
    st["view"] = jnp.where(fire, st["view"].at[:, g].set(load_g), st["view"])
    st["last_bcast"] = jnp.where(fire, st["last_bcast"].at[g].set(load_g),
                                 st["last_bcast"])
    st["beacons_tx"] = st["beacons_tx"] + jnp.where(fire, 1, 0)
    return st


def _handle_arrive(st, p: SimParams, t, app, g, _unused, lengths):
    """Stage 1: expand the fork tree at GMN g, fan out LOCAL_SPAWN msgs."""
    k, n = p.k, p.n_childs
    ns = int(min(k, max(1, -(-n // p.mpk))))      # cluster targets (static)
    depth = int(np.ceil(np.log2(ns))) if ns > 1 else 0
    share = n // ns
    rem = n - share * ns

    # GMN compute: the critical path of the binary fork tree does
    # 2 stage-1 decisions per level (paper Eqn 3: log(n) * Omega_s(k)).
    t_cpu = jnp.maximum(t, st["gmn_free"][g])
    t_tree = t_cpu + 2.0 * depth * p.sel_global
    st = dict(st)
    st["gmn_free"] = st["gmn_free"].at[g].set(t_tree)

    # own cluster count is exact (local data structure); remote via beacons
    own_view = st["view"][g].at[g].set(st["loads"][g].sum())
    # ties break starting from the searching GMN's own index (models the
    # hardware min-search starting at the local node) so identical stale
    # views at different GMNs don't all pick cluster 0
    perm = jnp.mod(jnp.arange(p.k) + g, p.k)

    def pick(carry, i):
        view, st_gbus = carry
        c = perm[jnp.argmin(view[perm])]           # stage-1 min-search
        cnt = share + jnp.where(i < rem, 1, 0)
        view = view.at[c].add(cnt)                 # optimistic local bookkeeping
        # task-start message over the global bus (serialized, c_b each);
        # a self-targeted spawn skips the bus
        is_remote = c != g
        t_bus = jnp.maximum(t_tree, st_gbus) + p.c_b
        st_gbus = jnp.where(is_remote, t_bus, st_gbus)
        t_arr = jnp.where(is_remote, t_bus, t_tree)
        return (view, st_gbus), (c, cnt, t_arr)

    (new_view, gbus), (cs, cnts, t_arrs) = jax.lax.scan(
        pick, (own_view, st["gbus_free"]), jnp.arange(ns))
    st["view"] = st["view"].at[g].set(new_view)
    st["gbus_free"] = gbus
    st["app_remaining"] = st["app_remaining"].at[app].set(n)
    st["app_arrive"] = st["app_arrive"].at[app].set(t)

    def push_one(st, i):
        return _push(st, t_arrs[i], EV_LOCAL_SPAWN, app, cs[i], cnts[i]), None

    st, _ = jax.lax.scan(push_one, st, jnp.arange(ns))
    return st


def _handle_local_spawn(st, p: SimParams, t, app, g, cnt, lengths):
    """Stage 2: GMN g maps cnt childs onto its PEs (exact local view)."""
    mpk, n_max = p.mpk, p.n_childs
    st = dict(st)

    def spawn(carry, i):
        t_cpu, lbus, pe_free, loads = carry
        active = i < cnt
        t_cpu = t_cpu + jnp.where(active, p.sel_local, 0.0)
        pe = jnp.argmin(loads)                     # stage-2 min-search
        # task-start over the local bus
        t_msg = jnp.maximum(t_cpu, lbus) + p.c_b
        lbus = jnp.where(active, t_msg, lbus)
        start = jnp.maximum(t_msg, pe_free[pe])
        ln = lengths[app, i]
        finish = start + ln
        pe_free = jnp.where(active, pe_free.at[pe].set(finish), pe_free)
        loads = jnp.where(active, loads.at[pe].add(1), loads)
        return (t_cpu, lbus, pe_free, loads), (pe, finish, active)

    t0 = jnp.maximum(t, st["gmn_free"][g])
    (t_cpu, lbus, pe_free, loads), (pes, finishes, actives) = jax.lax.scan(
        spawn, (t0, st["lbus_free"][g], st["pe_free"][g], st["loads"][g]),
        jnp.arange(n_max))
    st["gmn_free"] = st["gmn_free"].at[g].set(t_cpu)
    st["lbus_free"] = st["lbus_free"].at[g].set(lbus)
    st["pe_free"] = st["pe_free"].at[g].set(pe_free)
    st["loads"] = st["loads"].at[g].set(loads)

    st = _maybe_beacon(st, p, g, t_cpu)

    def push_exit(st, i):
        return jax.lax.cond(
            actives[i],
            lambda s: _push(s, finishes[i], EV_JOIN_EXIT, app, g, pes[i]),
            lambda s: s, st), None

    st, _ = jax.lax.scan(push_exit, st, jnp.arange(n_max))
    return st


def _handle_join_exit(st, p: SimParams, t, app, g, pe, lengths, parent_gmns):
    st = dict(st)
    # join-exit message over the local bus of the child's cluster
    t_msg = jnp.maximum(t, st["lbus_free"][g]) + p.c_b
    st["lbus_free"] = st["lbus_free"].at[g].set(t_msg)
    st["loads"] = st["loads"].at[g, pe].add(-1)
    st = _maybe_beacon(st, p, g, t_msg)
    # the join barrier lives at the application's arrival GMN: remote
    # join-exits forward over the global bus (Tab 2 / Sec 4)
    pg = parent_gmns[app]
    remote = pg != g
    t_fwd = jnp.where(remote,
                      jnp.maximum(t_msg, st["gbus_free"]) + p.c_b, t_msg)
    st["gbus_free"] = jnp.where(remote, t_fwd, st["gbus_free"])
    t_bar = jnp.maximum(t_fwd, st["gmn_free"][pg]) + p.c_join
    st["gmn_free"] = st["gmn_free"].at[pg].set(t_bar)
    rem = st["app_remaining"][app] - 1
    st["app_remaining"] = st["app_remaining"].at[app].set(rem)
    st["app_done"] = jnp.where(
        rem == 0, st["app_done"].at[app].set(t_bar), st["app_done"])
    return st


@functools.partial(jax.jit, static_argnums=(0,))
def run(p: SimParams, arrivals, arrival_gmns, lengths, sim_len: float = 1e7):
    """arrivals (A,) f32 times (INF = unused); arrival_gmns (A,) i32;
    lengths (A, n_childs) f32 child task lengths.

    Returns final state dict (response times = app_done - app_arrive).
    """
    st = make_state(p)

    def seed(st, i):
        return jax.lax.cond(
            arrivals[i] < sim_len,
            lambda s: _push(s, arrivals[i], EV_ARRIVE, i, arrival_gmns[i], 0),
            lambda s: s, st), None

    st, _ = jax.lax.scan(seed, st, jnp.arange(arrivals.shape[0]))

    def cond(st):
        return st["ev_time"].min() < INF

    def body(st):
        slot = jnp.argmin(st["ev_time"])
        t = st["ev_time"][slot]
        typ = st["ev_type"][slot]
        a = st["ev_a"][slot]
        st = dict(st)
        st["ev_time"] = st["ev_time"].at[slot].set(INF)   # recycle slot
        st["events_processed"] = st["events_processed"] + 1
        st = jax.lax.switch(
            typ,
            [lambda s: _handle_arrive(s, p, t, a[0], a[1], a[2], lengths),
             lambda s: _handle_local_spawn(s, p, t, a[0], a[1], a[2], lengths),
             lambda s: _handle_join_exit(s, p, t, a[0], a[1], a[2], lengths,
                                         arrival_gmns)],
            st)
        return st

    return jax.lax.while_loop(cond, body, st)


# --------------------------------------------------------------------------
# Metrics
# --------------------------------------------------------------------------

def response_times(final_state, arrivals):
    done = np.asarray(final_state["app_done"])
    arr = np.asarray(final_state["app_arrive"])
    ok = (done < 1e17) & (arr < 1e17)
    return (done - arr)[ok], ok


def speedup(final_state, arrivals, lengths):
    """S = t_seq / t_par, paper Sec 5; only completed apps count."""
    tr, ok = response_times(final_state, arrivals)
    if len(tr) == 0:
        return float("nan"), 0
    seq = np.asarray(lengths).sum(axis=1)[ok[: lengths.shape[0]]]
    return float(np.mean(seq / tr)), int(len(tr))
