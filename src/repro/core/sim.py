"""Event-driven transaction-level simulator of the clustered task manager.

Faithful JAX re-implementation of the paper's TLM evaluation (Sec 5):

  entities   k GMNs (serialized mapping compute, c_s per decision level),
             m PEs with FCFS queues, one global bus, k local buses
             (c_b per message, serialized per bus),
  mechanisms two-stage recursive task mapping (Sec 4.1), threshold-based
             status beacons (Sec 4.2, threshold dn_th), join/barrier
             synchronization (Tab 2).

All state lives in fixed-shape arrays; the run is one ``lax.while_loop``
over a bounded event queue.

Parameters are split into three objects (see DESIGN.md §7/§9):

  ``SimShape``   the shape-determining fields (m, k, n_childs, queue_cap,
                 max_apps).  Static JIT arguments — every distinct value
                 compiles one XLA program.
  ``SimPolicy``  the management strategy (mapping policy x beacon policy,
                 repro.core.policies).  Also static: each combination is
                 its own XLA program, so the untaken policy branches cost
                 nothing at run time.
  ``SimKnobs``   the numeric knobs (c_b, c_s, c_join, dn_th, T_b).  Traced
                 array arguments — changing them re-uses the compiled
                 program, and a batch of knob configs runs under
                 ``jax.vmap`` in a single compilation (repro.core.sweep).

``SimParams`` remains the user-facing bundle of all three; ``run(p, ...)``
is unchanged for callers.  Design-space sweeps over policies, thresholds,
costs and seeds go through ``repro.core.sweep`` which compiles once per
(shape, policy) pair.

Event types:
  ARRIVE(app)             application hits its stimulus GMN; the GMN expands
                          the recursive fork tree (stage-1 decisions over its
                          beacon view) and emits LOCAL_SPAWN messages.
  LOCAL_SPAWN(app, g, n)  cluster g maps n child tasks onto its PEs
                          (stage-2 min-search, exact local view), one
                          decision + one local-bus task-start per child.
  JOIN_EXIT(app, g, p)    child finished: local-bus join-exit message,
                          barrier decrement, load decrement, beacon check.

Deviations from the paper (documented in DESIGN.md §8): helper tasks occupy
the management plane (GMN time) rather than PEs; per-receiver beacon skew is
ignored (view updates atomically at bus-grant time).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import policies as P
from repro.core.policies import DEFAULT_POLICY, SimPolicy  # noqa: F401 (re-export)

INF = jnp.float32(1e18)

EV_ARRIVE = 0
EV_LOCAL_SPAWN = 1
EV_JOIN_EXIT = 2


@dataclass(frozen=True)
class SimShape:
    """Shape-determining simulator parameters.  Hashable and static: one
    XLA compilation per distinct value."""
    m: int = 256                 # processing elements
    k: int = 16                  # global management nodes (clusters)
    n_childs: int = 100          # child tasks per application
    queue_cap: int = 2048
    max_apps: int = 512

    @property
    def mpk(self) -> int:
        return self.m // self.k


class SimKnobs(NamedTuple):
    """Traced numeric knobs — a JAX pytree.  Stack leaves along a leading
    axis to form a batch of configs for ``repro.core.sweep``."""
    c_b: jnp.ndarray             # f32, message delay (4 tx + 4 rx)
    c_s: jnp.ndarray             # f32, selection delay coefficient
    c_join: jnp.ndarray          # f32, GMN barrier-decrement processing
    dn_th: jnp.ndarray           # i32, beacon drift threshold
    T_b: jnp.ndarray             # f32, beacon period/deadline (periodic,
                                 #      hybrid, staleness_weighted)

    @classmethod
    def make(cls, c_b=8.0, c_s=8.0, c_join=8.0, dn_th=4,
             T_b=1000.0) -> "SimKnobs":
        return cls(jnp.asarray(c_b, jnp.float32),
                   jnp.asarray(c_s, jnp.float32),
                   jnp.asarray(c_join, jnp.float32),
                   jnp.asarray(dn_th, jnp.int32),
                   jnp.asarray(T_b, jnp.float32))


@dataclass(frozen=True)
class SimParams:
    m: int = 256                 # processing elements
    k: int = 16                  # global management nodes (clusters)
    c_b: float = 8.0             # message delay (4 tx + 4 rx), bus-serialized
    c_s: float = 8.0             # selection delay coefficient
    c_join: float = 8.0          # GMN barrier-decrement processing
    dn_th: int = 4               # beacon drift threshold
    n_childs: int = 100          # child tasks per application
    queue_cap: int = 2048
    max_apps: int = 512
    T_b: float = 1000.0          # beacon period/deadline (traced knob)
    mapping: str = "min_search"  # stage-1 policy (static, core/policies.py)
    beacon: str = "threshold"    # beacon policy (static, core/policies.py)

    @property
    def mpk(self) -> int:
        return self.m // self.k

    @property
    def shape(self) -> SimShape:
        return SimShape(m=self.m, k=self.k, n_childs=self.n_childs,
                        queue_cap=self.queue_cap, max_apps=self.max_apps)

    @property
    def knobs(self) -> SimKnobs:
        return SimKnobs.make(c_b=self.c_b, c_s=self.c_s, c_join=self.c_join,
                             dn_th=self.dn_th, T_b=self.T_b)

    @property
    def policy(self) -> SimPolicy:
        return SimPolicy(mapping=self.mapping, beacon=self.beacon)

    @property
    def sel_global(self) -> float:
        """Stage-1 decision cost c_s * log2(k) (same formula the traced
        _Ctx uses)."""
        return self.c_s * _log2_levels(self.k)

    @property
    def sel_local(self) -> float:
        """Stage-2 decision cost c_s * log2(m/k) (same formula the traced
        _Ctx uses)."""
        return self.c_s * _log2_levels(self.mpk)


def _log2_levels(v: int) -> float:
    """Static decision-tree depth factor: log2(v) for v > 1, else 0."""
    return float(np.log2(v)) if v > 1 else 0.0


class _Ctx:
    """Per-trace context: static shape ints + policy + traced knob scalars,
    presented through the attribute names the event handlers historically
    used."""
    __slots__ = ("m", "k", "mpk", "n_childs", "queue_cap", "max_apps",
                 "c_b", "c_s", "c_join", "dn_th", "T_b", "policy",
                 "sel_global", "sel_local")

    def __init__(self, shape: SimShape, knobs: SimKnobs,
                 policy: SimPolicy = DEFAULT_POLICY):
        self.m = shape.m
        self.k = shape.k
        self.mpk = shape.mpk
        self.n_childs = shape.n_childs
        self.queue_cap = shape.queue_cap
        self.max_apps = shape.max_apps
        self.c_b = knobs.c_b
        self.c_s = knobs.c_s
        self.c_join = knobs.c_join
        self.dn_th = knobs.dn_th
        self.T_b = knobs.T_b
        self.policy = policy
        self.sel_global = knobs.c_s * _log2_levels(shape.k)
        self.sel_local = knobs.c_s * _log2_levels(shape.mpk)


def make_state(p):
    k, mpk, Q, A = p.k, p.mpk, p.queue_cap, p.max_apps
    return {
        # event queue (slot-recycled)
        "ev_time": jnp.full((Q,), INF),
        "ev_type": jnp.zeros((Q,), jnp.int32),
        "ev_a": jnp.zeros((Q, 3), jnp.int32),      # (app, gmn/cluster, pe/cnt)
        # infra
        "pe_free": jnp.zeros((k, mpk), jnp.float32),
        "gmn_free": jnp.zeros((k,), jnp.float32),
        "gbus_free": jnp.zeros((), jnp.float32),
        "lbus_free": jnp.zeros((k,), jnp.float32),
        # load bookkeeping
        "loads": jnp.zeros((k, mpk), jnp.int32),   # mapped tasks per PE
        "view": jnp.zeros((k, k), jnp.int32),      # GMN g's view of cluster c
        "view_t": jnp.zeros((k, k), jnp.float32),  # tick view[g, c] was recvd
        "last_bcast": jnp.zeros((k,), jnp.int32),
        "last_bcast_t": jnp.zeros((k,), jnp.float32),
        "rr_ptr": jnp.zeros((k,), jnp.int32),      # per-GMN decision counter
        "beacons_tx": jnp.zeros((), jnp.int32),
        # applications
        "app_remaining": jnp.zeros((A,), jnp.int32),
        "app_arrive": jnp.full((A,), INF),
        "app_done": jnp.full((A,), INF),
        "events_processed": jnp.zeros((), jnp.int32),
        "dropped": jnp.zeros((), jnp.int32),
    }


# Dynamic-index updates are written as one-hot selects rather than
# ``.at[i].set``: under vmap a per-lane index can't lower to a
# dynamic-update-slice, and XLA:CPU's general scatter is a serial loop that
# dominates batched-sweep runtime.  The selects compute identical values
# (no arithmetic on unselected elements), which keeps sweep results bitwise
# equal to per-config runs (tests/test_sweep.py).

def _set1(arr, i, val):
    """arr.at[i].set(val) as a one-hot select (row update for ndim > 1)."""
    hot = jnp.arange(arr.shape[0]) == i
    return jnp.where(hot.reshape((-1,) + (1,) * (arr.ndim - 1)), val, arr)


def _setcol(arr, j, val):
    """arr.at[:, j].set(val) as a one-hot select."""
    return jnp.where(jnp.arange(arr.shape[1])[None, :] == j, val, arr)


def _add1(arr, i, delta):
    """arr.at[i].add(delta) as a one-hot select."""
    return jnp.where(jnp.arange(arr.shape[0]) == i, arr + delta, arr)


def _add2(arr, i, j, delta):
    """arr.at[i, j].add(delta) as a one-hot select."""
    hot = (jnp.arange(arr.shape[0])[:, None] == i) \
        & (jnp.arange(arr.shape[1])[None, :] == j)
    return jnp.where(hot, arr + delta, arr)


def _bulk_push(st, mask, times, typ, a0, a1, a2):
    """Insert the masked entries of an event batch, exactly equivalent to
    pushing them one by one in order (the j-th masked entry takes the j-th
    free queue slot, matching the historical first-free-slot search), but
    as one vectorized pass over the queue — the sequential version costs a
    queue-wide scan per entry, which dominated batched-sweep runtime."""
    n = times.shape[0]
    free = st["ev_time"] >= INF
    free_rank = jnp.cumsum(free) - 1                 # slot's rank among free
    cnt = mask.sum()
    order = jnp.argsort(jnp.logical_not(mask))       # stable: pushed first
    idx = jnp.minimum(free_rank, n - 1)
    ct = times[order][idx]
    ca = jnp.stack([a0[order][idx], a1[order][idx], a2[order][idx]], -1)
    write = free & (free_rank < cnt)
    st = dict(st)
    st["ev_time"] = jnp.where(write, ct, st["ev_time"])
    st["ev_type"] = jnp.where(write, typ, st["ev_type"])
    st["ev_a"] = jnp.where(write[:, None], ca, st["ev_a"])
    st["dropped"] = st["dropped"] + jnp.maximum(cnt - free.sum(), 0)
    return st


def _maybe_beacon(st, p, g, t):
    """Status broadcast check (Sec 4.2, generalized).  The trigger is the
    statically selected BeaconPolicy (core/policies.py); ``threshold`` is
    the paper's drift rule, and the `k > 1` gate is topology, not policy."""
    load_g = st["loads"][g].sum()
    delta = jnp.abs(load_g - st["last_bcast"][g])
    due = P.beacon_policy(p.policy.beacon)(
        delta, t, st["last_bcast_t"][g], dn_th=p.dn_th, T_b=p.T_b)
    fire = jnp.logical_and(due, p.k > 1)
    # bus grant: serialize on the global bus
    t_tx = jnp.maximum(t, st["gbus_free"]) + p.c_b
    st = dict(st)
    st["gbus_free"] = jnp.where(fire, t_tx, st["gbus_free"])
    st["view"] = jnp.where(fire, _setcol(st["view"], g, load_g), st["view"])
    st["view_t"] = jnp.where(fire, _setcol(st["view_t"], g, t_tx),
                             st["view_t"])
    st["last_bcast"] = jnp.where(fire, _set1(st["last_bcast"], g, load_g),
                                 st["last_bcast"])
    st["last_bcast_t"] = jnp.where(fire, _set1(st["last_bcast_t"], g, t_tx),
                                   st["last_bcast_t"])
    st["beacons_tx"] = st["beacons_tx"] + jnp.where(fire, 1, 0)
    return st


def _handle_arrive(st, p, t, app, g, _unused, lengths):
    """Stage 1: expand the fork tree at GMN g, fan out LOCAL_SPAWN msgs."""
    k, n = p.k, p.n_childs
    ns = int(min(k, max(1, -(-n // p.mpk))))      # cluster targets (static)
    depth = int(np.ceil(np.log2(ns))) if ns > 1 else 0
    share = n // ns
    rem = n - share * ns

    # GMN compute: the critical path of the binary fork tree does
    # 2 stage-1 decisions per level (paper Eqn 3: log(n) * Omega_s(k)).
    t_cpu = jnp.maximum(t, st["gmn_free"][g])
    t_tree = t_cpu + 2.0 * depth * p.sel_global
    st = dict(st)
    st["gmn_free"] = _set1(st["gmn_free"], g, t_tree)

    # own cluster count is exact (local data structure); remote via beacons
    own_view = _set1(st["view"][g], g, st["loads"][g].sum())
    # beacon ages feed the staleness-aware policies; own entry always fresh
    age = _set1(jnp.maximum(t - st["view_t"][g], 0.0), g, 0.0)
    # stage-1 cluster choice is the statically selected MappingPolicy
    # (core/policies.py); min_search reproduces the historical inline rule
    # bitwise (min over the view, ties from the GMN's own index)
    pick_cluster = P.mapping_policy(p.policy.mapping)

    def pick(carry, i):
        view, st_gbus, rr = carry
        c = pick_cluster(view, age, g, rr, app, i, k=p.k, T_b=p.T_b)
        cnt = share + jnp.where(i < rem, 1, 0)
        view = _add1(view, c, cnt)                 # optimistic local bookkeeping
        # task-start message over the global bus (serialized, c_b each);
        # a self-targeted spawn skips the bus
        is_remote = c != g
        t_bus = jnp.maximum(t_tree, st_gbus) + p.c_b
        st_gbus = jnp.where(is_remote, t_bus, st_gbus)
        t_arr = jnp.where(is_remote, t_bus, t_tree)
        return (view, st_gbus, rr + 1), (c, cnt, t_arr)

    (new_view, gbus, rr_out), (cs, cnts, t_arrs) = jax.lax.scan(
        pick, (own_view, st["gbus_free"], st["rr_ptr"][g]), jnp.arange(ns))
    st["view"] = _set1(st["view"], g, new_view)
    st["rr_ptr"] = _set1(st["rr_ptr"], g, rr_out)
    st["gbus_free"] = gbus
    st["app_remaining"] = _set1(st["app_remaining"], app, n)
    st["app_arrive"] = _set1(st["app_arrive"], app, t)

    return _bulk_push(st, jnp.ones((ns,), bool), t_arrs, EV_LOCAL_SPAWN,
                      jnp.full((ns,), app), cs, cnts)


def _spawn_group_bound(p) -> int:
    """Static upper bound on childs per LOCAL_SPAWN group: _handle_arrive
    hands each of its ns targets share or share+1 childs."""
    k, n = p.k, p.n_childs
    ns = int(min(k, max(1, -(-n // p.mpk))))
    share = n // ns
    return min(p.n_childs, share + (1 if n - share * ns > 0 else 0))


def _handle_local_spawn(st, p, t, app, g, cnt, lengths):
    """Stage 2: GMN g maps cnt childs onto its PEs (exact local view)."""
    mpk = p.mpk
    n_max = _spawn_group_bound(p)   # static; cnt <= n_max always
    st = dict(st)

    def spawn(carry, i):
        t_cpu, lbus, pe_free, loads = carry
        active = i < cnt
        t_cpu = t_cpu + jnp.where(active, p.sel_local, 0.0)
        pe = jnp.argmin(loads)                     # stage-2 min-search
        # task-start over the local bus
        t_msg = jnp.maximum(t_cpu, lbus) + p.c_b
        lbus = jnp.where(active, t_msg, lbus)
        start = jnp.maximum(t_msg, pe_free[pe])
        ln = lengths[app, i]
        finish = start + ln
        pe_free = jnp.where(active, _set1(pe_free, pe, finish), pe_free)
        loads = jnp.where(active, _add1(loads, pe, 1), loads)
        return (t_cpu, lbus, pe_free, loads), (pe, finish, active)

    t0 = jnp.maximum(t, st["gmn_free"][g])
    (t_cpu, lbus, pe_free, loads), (pes, finishes, actives) = jax.lax.scan(
        spawn, (t0, st["lbus_free"][g], st["pe_free"][g], st["loads"][g]),
        jnp.arange(n_max))
    st["gmn_free"] = _set1(st["gmn_free"], g, t_cpu)
    st["lbus_free"] = _set1(st["lbus_free"], g, lbus)
    st["pe_free"] = _set1(st["pe_free"], g, pe_free)
    st["loads"] = _set1(st["loads"], g, loads)

    st = _maybe_beacon(st, p, g, t_cpu)

    return _bulk_push(st, actives, finishes, EV_JOIN_EXIT,
                      jnp.full((n_max,), app), jnp.full((n_max,), g), pes)


def _handle_join_exit(st, p, t, app, g, pe, lengths, parent_gmns):
    st = dict(st)
    # join-exit message over the local bus of the child's cluster
    t_msg = jnp.maximum(t, st["lbus_free"][g]) + p.c_b
    st["lbus_free"] = _set1(st["lbus_free"], g, t_msg)
    st["loads"] = _add2(st["loads"], g, pe, -1)
    st = _maybe_beacon(st, p, g, t_msg)
    # the join barrier lives at the application's arrival GMN: remote
    # join-exits forward over the global bus (Tab 2 / Sec 4)
    pg = parent_gmns[app]
    remote = pg != g
    t_fwd = jnp.where(remote,
                      jnp.maximum(t_msg, st["gbus_free"]) + p.c_b, t_msg)
    st["gbus_free"] = jnp.where(remote, t_fwd, st["gbus_free"])
    t_bar = jnp.maximum(t_fwd, st["gmn_free"][pg]) + p.c_join
    st["gmn_free"] = _set1(st["gmn_free"], pg, t_bar)
    rem = st["app_remaining"][app] - 1
    st["app_remaining"] = _set1(st["app_remaining"], app, rem)
    st["app_done"] = jnp.where(
        rem == 0, _set1(st["app_done"], app, t_bar), st["app_done"])
    return st


def simulate(shape: SimShape, knobs: SimKnobs, arrivals, arrival_gmns,
             lengths, sim_len, policy: SimPolicy = DEFAULT_POLICY):
    """Traceable core: static ``shape`` and ``policy``, traced everything
    else.  This is what ``repro.core.sweep`` vmaps over knob/workload
    batches (one XLA program per (shape, policy) pair)."""
    p = _Ctx(shape, knobs, policy)
    st = make_state(p)

    n_apps = arrivals.shape[0]
    st = _bulk_push(st, arrivals < sim_len, arrivals, EV_ARRIVE,
                    jnp.arange(n_apps), arrival_gmns,
                    jnp.zeros((n_apps,), jnp.int32))

    def cond(st):
        return st["ev_time"].min() < INF

    def body(st):
        slot = jnp.argmin(st["ev_time"])
        t = st["ev_time"][slot]
        typ = st["ev_type"][slot]
        a = st["ev_a"][slot]
        st = dict(st)
        st["ev_time"] = _set1(st["ev_time"], slot, INF)   # recycle slot
        st["events_processed"] = st["events_processed"] + 1
        st = jax.lax.switch(
            typ,
            [lambda s: _handle_arrive(s, p, t, a[0], a[1], a[2], lengths),
             lambda s: _handle_local_spawn(s, p, t, a[0], a[1], a[2], lengths),
             lambda s: _handle_join_exit(s, p, t, a[0], a[1], a[2], lengths,
                                         arrival_gmns)],
            st)
        return st

    return jax.lax.while_loop(cond, body, st)


_run = jax.jit(simulate, static_argnums=(0, 6))


def run(p: SimParams, arrivals, arrival_gmns, lengths, sim_len: float = 1e7):
    """arrivals (A,) f32 times (INF = unused); arrival_gmns (A,) i32;
    lengths (A, n_childs) f32 child task lengths.

    Returns final state dict (response times = app_done - app_arrive).
    Compiles once per ``(p.shape, p.policy)``; the numeric knobs (c_b,
    c_s, c_join, dn_th, T_b) and sim_len are traced, so threshold/cost/
    period sweeps re-use the compiled program.
    """
    return _run(p.shape, p.knobs,
                jnp.asarray(arrivals, jnp.float32),
                jnp.asarray(arrival_gmns, jnp.int32),
                jnp.asarray(lengths, jnp.float32),
                jnp.float32(sim_len), p.policy)


def compile_cache_size() -> int:
    """Number of XLA programs compiled for ``run`` (one per
    (SimShape, SimPolicy) pair).
    Relies on jit's private cache introspection; returns 0 if a future
    JAX drops it (degrading compile-count reporting, not simulation)."""
    counter = getattr(_run, "_cache_size", None)
    return counter() if callable(counter) else 0


# --------------------------------------------------------------------------
# Metrics
# --------------------------------------------------------------------------

def response_times(final_state, arrivals):
    done = np.asarray(final_state["app_done"])
    arr = np.asarray(final_state["app_arrive"])
    ok = (done < 1e17) & (arr < 1e17)
    return (done - arr)[ok], ok


def speedup(final_state, arrivals, lengths):
    """S = t_seq / t_par, paper Sec 5; only completed apps count."""
    tr, ok = response_times(final_state, arrivals)
    if len(tr) == 0:
        return float("nan"), 0
    seq = np.asarray(lengths).sum(axis=1)[ok[: lengths.shape[0]]]
    return float(np.mean(seq / tr)), int(len(tr))
