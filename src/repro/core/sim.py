"""Event-driven transaction-level simulator of the clustered task manager.

Faithful JAX re-implementation of the paper's TLM evaluation (Sec 5):

  entities   k GMNs (serialized mapping compute, c_s per decision level),
             m PEs with FCFS queues, one global bus, k local buses
             (c_b per message, serialized per bus),
  mechanisms two-stage recursive task mapping (Sec 4.1), threshold-based
             status beacons (Sec 4.2, threshold dn_th), join/barrier
             synchronization (Tab 2).

All state lives in fixed-shape arrays; the run is one ``lax.while_loop``
over a bounded event queue.  The queue's priority structure is itself a
static axis (``queue_impl``, core/eventq.py, DESIGN.md §11): ``"linear"``
pops with an O(queue_cap) ``jnp.argmin`` scan — the historical code,
kept operation-for-operation as the golden anchor — while ``"tree"``
maintains a static-depth tournament tree for O(log queue_cap) pop/push
with bitwise-identical results, which is what makes the paper-scale
m=256/k=256 distributed runs tractable on CPU
(benchmarks/topology_frontier.py --grid paper).

Parameters are split into three objects (see DESIGN.md §7/§9):

  ``SimShape``   the shape-determining fields (m, k, n_childs, queue_cap,
                 max_apps).  Static JIT arguments — every distinct value
                 compiles one XLA program.
  ``SimPolicy``  the management strategy (mapping policy x beacon policy,
                 repro.core.policies).  Also static: each combination is
                 its own XLA program, so the untaken policy branches cost
                 nothing at run time.
  ``SimKnobs``   the numeric knobs (c_b, c_s, c_join, dn_th, T_b).  Traced
                 array arguments — changing them re-uses the compiled
                 program, and a batch of knob configs runs under
                 ``jax.vmap`` in a single compilation (repro.core.sweep).

``SimParams`` remains the user-facing bundle of all three; ``run(p, ...)``
is unchanged for callers.  Design-space sweeps over policies, thresholds,
costs and seeds go through ``repro.core.sweep`` which compiles once per
(shape, policy) pair.

All management messages (task-start groups, join-exits and their
forwards, status beacons) route through the interconnect transport model
(``repro.core.transport``, DESIGN.md §10).  The fabric is a fourth
static axis next to shape and policy: ``Topology("ideal")`` reproduces
the historical single-global-bus behavior bitwise, while ``shared_bus``
/ ``hier_tree`` / ``mesh2d`` model contention and per-receiver beacon
skew — a fired beacon becomes k-1 in-flight entries in the ``(k, k)``
``bcn_t``/``bcn_val`` delivery matrix plus one BEACON_RX event per
receiver, so each GMN's ``view_t`` (and hence the staleness ``age`` fed
to the mapping policies) is genuinely heterogeneous.

Event types:
  ARRIVE(app)             application hits its stimulus GMN; the GMN expands
                          the recursive fork tree (stage-1 decisions over its
                          beacon view) and emits LOCAL_SPAWN messages.
  LOCAL_SPAWN(app, g, n)  cluster g maps n child tasks onto its PEs
                          (stage-2 min-search, exact local view), one
                          decision + one local-bus task-start per child.
  JOIN_EXIT(app, g, p)    child finished: local-bus join-exit message,
                          barrier decrement, load decrement, beacon check.
  BEACON_RX(src, rcv, v)  (non-ideal topologies only) the in-flight beacon
                          from GMN src reaches receiver rcv carrying load
                          summary v; rcv's view/view_t update here.
  LINK_DOWN(i, j)         fault injection (repro.core.faults, DESIGN.md §13):
  LINK_UP(i, j)           the directed (i, j) entry of the traced ``link_up``
                          mask flips; UP accounts the completed outage into
                          ``downtime``.
  GMN_FAIL(g)             GMN g dies / recovers: the ``gmn_alive`` vector
  GMN_HEAL(g)             flips, and management work addressed to a dead GMN
                          re-homes to the least-loaded live GMN (min_search
                          takeover, ``_takeover``) counting ``reroutes``.

The fault machinery compiles in only when a ``FaultSchedule`` is passed
(``faults`` is a traced pytree argument: a schedule *grid* — different
seeds, intensities, scenarios of the same length — re-uses one XLA
program, just like a knob grid).  With every link up and every GMN
alive the fault-aware code paths are exact no-ops, so a run under the
empty ``FaultSpec.none()`` schedule reproduces the frozen no-fault
goldens bitwise (tests/test_faults.py).

Deviations from the paper (documented in DESIGN.md §8): helper tasks occupy
the management plane (GMN time) rather than PEs.  Per-receiver beacon skew
(former deviation §8.2) is now modeled by the non-ideal topologies; the
default ``ideal`` fabric retains the atomic-update behavior for bitwise
continuity with the published golden results.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import eventq as EQ
from repro.core import faults as FLT
from repro.core import policies as P
from repro.core import transport as T
from repro.core.eventq import QUEUE_IMPLS  # noqa: F401 (re-export)
# the single INF sentinel both queue impls compare against — the
# linear/tree bitwise contract hinges on it being one shared value
from repro.core.eventq import INF
from repro.core.policies import DEFAULT_POLICY, SimPolicy  # noqa: F401 (re-export)
from repro.core.transport import DEFAULT_TOPOLOGY, Topology  # noqa: F401 (re-export)

EV_ARRIVE = 0
EV_LOCAL_SPAWN = 1
EV_JOIN_EXIT = 2
EV_BEACON_RX = 3
# fault events (compiled in only when a FaultSchedule is passed);
# EV == EV_LINK_DOWN + faults.F_* kind
EV_LINK_DOWN = 4
EV_LINK_UP = 5
EV_GMN_FAIL = 6
EV_GMN_HEAL = 7

# stage-1 view tombstone for dead clusters: large enough that every
# mapping policy's min-search avoids them, small enough that i32
# arithmetic on the masked view cannot overflow
_DEAD_VIEW = jnp.int32(1 << 30)


@dataclass(frozen=True)
class SimShape:
    """Shape-determining simulator parameters.  Hashable and static: one
    XLA compilation per distinct value."""
    m: int = 256                 # processing elements
    k: int = 16                  # global management nodes (clusters)
    n_childs: int = 100          # child tasks per application
    queue_cap: int = 2048
    max_apps: int = 512
    record_s1: bool = False      # record per-decision stage-1 traces
                                 # (view/age/choice) for serving.replay
    queue_impl: str = "linear"   # event-queue structure (core/eventq.py):
                                 # "linear" = O(Q) argmin scan (golden
                                 # anchor), "tree" = O(log Q) tournament
                                 # tree, bitwise-identical results

    def __post_init__(self):
        if self.queue_impl not in QUEUE_IMPLS:
            raise ValueError(f"unknown queue_impl {self.queue_impl!r}; "
                             f"choose from {QUEUE_IMPLS}")

    @property
    def mpk(self) -> int:
        return self.m // self.k

    @property
    def ns(self) -> int:
        """Static stage-1 fan-out: cluster targets per application."""
        return stage1_targets(self)


def stage1_targets(shape) -> int:
    """Static number of LOCAL_SPAWN targets per ARRIVE (Sec 4.1)."""
    return int(min(shape.k, max(1, -(-shape.n_childs // shape.mpk))))


class SimKnobs(NamedTuple):
    """Traced numeric knobs — a JAX pytree.  Stack leaves along a leading
    axis to form a batch of configs for ``repro.core.sweep``."""
    c_b: jnp.ndarray             # f32, message delay (4 tx + 4 rx)
    c_s: jnp.ndarray             # f32, selection delay coefficient
    c_join: jnp.ndarray          # f32, GMN barrier-decrement processing
    dn_th: jnp.ndarray           # i32, beacon drift threshold
    T_b: jnp.ndarray             # f32, beacon period/deadline (periodic,
                                 #      hybrid, staleness_weighted)
    c_hop: jnp.ndarray           # f32, per-hop mesh latency (mesh2d)

    @classmethod
    def make(cls, c_b=8.0, c_s=8.0, c_join=8.0, dn_th=4,
             T_b=1000.0, c_hop=2.0) -> "SimKnobs":
        return cls(jnp.asarray(c_b, jnp.float32),
                   jnp.asarray(c_s, jnp.float32),
                   jnp.asarray(c_join, jnp.float32),
                   jnp.asarray(dn_th, jnp.int32),
                   jnp.asarray(T_b, jnp.float32),
                   jnp.asarray(c_hop, jnp.float32))


@dataclass(frozen=True)
class SimParams:
    m: int = 256                 # processing elements
    k: int = 16                  # global management nodes (clusters)
    c_b: float = 8.0             # message delay (4 tx + 4 rx), bus-serialized
    c_s: float = 8.0             # selection delay coefficient
    c_join: float = 8.0          # GMN barrier-decrement processing
    dn_th: int = 4               # beacon drift threshold
    n_childs: int = 100          # child tasks per application
    queue_cap: int = 2048
    max_apps: int = 512
    T_b: float = 1000.0          # beacon period/deadline (traced knob)
    c_hop: float = 2.0           # per-hop mesh latency (traced knob)
    mapping: str = "min_search"  # stage-1 policy (static, core/policies.py)
    beacon: str = "threshold"    # beacon policy (static, core/policies.py)
    topology: str = "ideal"      # fabric model (static, core/transport.py)
    record_s1: bool = False      # record stage-1 decision traces (replay)
    queue_impl: str = "linear"   # event-queue structure (core/eventq.py)

    def __post_init__(self):
        if self.queue_impl not in QUEUE_IMPLS:
            raise ValueError(f"unknown queue_impl {self.queue_impl!r}; "
                             f"choose from {QUEUE_IMPLS}")

    @property
    def mpk(self) -> int:
        return self.m // self.k

    @property
    def shape(self) -> SimShape:
        return SimShape(m=self.m, k=self.k, n_childs=self.n_childs,
                        queue_cap=self.queue_cap, max_apps=self.max_apps,
                        record_s1=self.record_s1,
                        queue_impl=self.queue_impl)

    @property
    def knobs(self) -> SimKnobs:
        return SimKnobs.make(c_b=self.c_b, c_s=self.c_s, c_join=self.c_join,
                             dn_th=self.dn_th, T_b=self.T_b, c_hop=self.c_hop)

    @property
    def policy(self) -> SimPolicy:
        return SimPolicy(mapping=self.mapping, beacon=self.beacon)

    @property
    def topo(self) -> Topology:
        return Topology(kind=self.topology)

    @property
    def sel_global(self) -> float:
        """Stage-1 decision cost c_s * log2(k) (same formula the traced
        _Ctx uses)."""
        return self.c_s * _log2_levels(self.k)

    @property
    def sel_local(self) -> float:
        """Stage-2 decision cost c_s * log2(m/k) (same formula the traced
        _Ctx uses)."""
        return self.c_s * _log2_levels(self.mpk)


def _log2_levels(v: int) -> float:
    """Static decision-tree depth factor: log2(v) for v > 1, else 0."""
    return float(np.log2(v)) if v > 1 else 0.0


class _Ctx:
    """Per-trace context: static shape ints + policy + topology + traced
    knob scalars, presented through the attribute names the event handlers
    historically used."""
    __slots__ = ("m", "k", "mpk", "n_childs", "queue_cap", "max_apps",
                 "c_b", "c_s", "c_join", "dn_th", "T_b", "c_hop", "policy",
                 "topology", "hops", "ns", "record_s1", "queue_impl",
                 "qdepth", "sel_global", "sel_local", "faults_on")

    def __init__(self, shape: SimShape, knobs: SimKnobs,
                 policy: SimPolicy = DEFAULT_POLICY,
                 topology: Topology = DEFAULT_TOPOLOGY,
                 faults_on: bool = False):
        self.m = shape.m
        self.k = shape.k
        self.mpk = shape.mpk
        self.n_childs = shape.n_childs
        self.queue_cap = shape.queue_cap
        self.max_apps = shape.max_apps
        self.c_b = knobs.c_b
        self.c_s = knobs.c_s
        self.c_join = knobs.c_join
        self.dn_th = knobs.dn_th
        self.T_b = knobs.T_b
        self.c_hop = knobs.c_hop
        self.policy = policy
        self.topology = topology
        # static Manhattan hop table (XLA constant; only mesh2d reads it)
        self.hops = jnp.asarray(T.mesh_hops(shape.k))
        self.ns = shape.ns
        self.record_s1 = shape.record_s1
        self.queue_impl = shape.queue_impl
        self.qdepth = EQ.tree_depth(shape.queue_cap)   # static tree depth
        self.sel_global = knobs.c_s * _log2_levels(shape.k)
        self.sel_local = knobs.c_s * _log2_levels(shape.mpk)
        # static: whether the fault machinery (mask state, fault event
        # branches, mask-routed message paths) is compiled in
        self.faults_on = faults_on


def make_state(p):
    k, mpk, Q, A = p.k, p.mpk, p.queue_cap, p.max_apps
    tree = getattr(p, "queue_impl", "linear") == "tree"
    return ({
        # tournament tree (core/eventq.py, DESIGN.md §11): times AND
        # payloads live in the tree rows; the ev_* arrays below do not
        # exist in tree mode
        } | EQ.queue_state(Q) if tree else {
        # event queue (slot-recycled)
        "ev_time": jnp.full((Q,), INF),
        "ev_type": jnp.zeros((Q,), jnp.int32),
        "ev_a": jnp.zeros((Q, 3), jnp.int32),      # (app, gmn/cluster, pe/cnt)
    }) | {
        # infra
        "pe_free": jnp.zeros((k, mpk), jnp.float32),
        "gmn_free": jnp.zeros((k,), jnp.float32),
        "gbus_free": jnp.zeros((), jnp.float32),
        "lbus_free": jnp.zeros((k,), jnp.float32),
        # load bookkeeping
        "loads": jnp.zeros((k, mpk), jnp.int32),   # mapped tasks per PE
        "view": jnp.zeros((k, k), jnp.int32),      # GMN g's view of cluster c
        "view_t": jnp.zeros((k, k), jnp.float32),  # tick view[g, c] was recvd
        "last_bcast": jnp.zeros((k,), jnp.int32),
        "last_bcast_t": jnp.zeros((k,), jnp.float32),
        "rr_ptr": jnp.zeros((k,), jnp.int32),      # per-GMN decision counter
        "beacons_tx": jnp.zeros((), jnp.int32),
        # transport: in-flight beacon matrix [src, rcv] tracking the
        # LATEST pending arrival per pair (non-ideal topologies; stays
        # INF under "ideal") + the delivery counter — conservation is
        # exact: beacons_rx == (k-1) * beacons_tx at the end of a run
        "bcn_t": jnp.full((k, k), INF),            # arrival time (INF = none)
        "beacons_rx": jnp.zeros((), jnp.int32),    # per-receiver deliveries
        # per-receiver delivery skew of each fired beacon (max - min
        # arrival): the heterogeneity the ideal fabric hides
        "bcn_skew_sum": jnp.zeros((), jnp.float32),
        "bcn_skew_max": jnp.zeros((), jnp.float32),
        # management accounting (benchmarks/topology_frontier.py):
        # mgmt_latency sums (delivery - ready) over transported messages —
        # the pure communication overhead, broken out per fabric;
        # mgmt_proc sums manager-side queueing + service (fork expansion,
        # stage-2 decision batches, barrier decrements) — the computation
        # overhead that saturates a centralized manager
        "mgmt_msgs": jnp.zeros((), jnp.int32),
        "mgmt_latency": jnp.zeros((), jnp.float32),
        "mgmt_proc": jnp.zeros((), jnp.float32),
        # applications
        "app_remaining": jnp.zeros((A,), jnp.int32),
        "app_arrive": jnp.full((A,), INF),
        "app_done": jnp.full((A,), INF),
        "events_processed": jnp.zeros((), jnp.int32),
        "dropped": jnp.zeros((), jnp.int32),
    } | ({
        # fault fabric state (repro.core.faults, DESIGN.md §13): the
        # traced link mask + GMN liveness the message paths route
        # through, outage-start bookkeeping, and the availability
        # counters of the overhead decomposition.  Only present when a
        # FaultSchedule is passed (the fault-aware program).
        "link_up": jnp.ones((k, k), jnp.float32),     # directed, 1 = up
        "gmn_alive": jnp.ones((k,), jnp.float32),     # 1 = alive
        "link_down_t": jnp.zeros((k, k), jnp.float32),
        "gmn_down_t": jnp.zeros((k,), jnp.float32),
        "msgs_lost": jnp.zeros((), jnp.int32),    # dropped beacon deliveries
        "reroutes": jnp.zeros((), jnp.int32),     # detours + re-homed work
        "downtime": jnp.zeros((), jnp.float32),   # completed outage ticks
    } if getattr(p, "faults_on", False) else {}) | ({
        # stage-1 decision trace (serving/replay.py cross-validation)
        "dec_view": jnp.zeros((A, p.ns, k), jnp.int32),
        "dec_age": jnp.zeros((A, k), jnp.float32),
        "dec_choice": jnp.zeros((A, p.ns), jnp.int32),
        "dec_rr0": jnp.zeros((A,), jnp.int32),
        "dec_t": jnp.full((A,), INF),
    } if p.record_s1 else {}) | ({
        # under faults the deciding GMN can differ from the stimulus GMN
        # (min_search takeover); replay needs the effective decider
        "dec_gmn": jnp.zeros((A,), jnp.int32),
    } if p.record_s1 and getattr(p, "faults_on", False) else {})


# Dynamic-index updates are written as one-hot selects rather than
# ``.at[i].set``: under vmap a per-lane index can't lower to a
# dynamic-update-slice, and XLA:CPU's general scatter is a serial loop that
# dominates batched-sweep runtime.  The selects compute identical values
# (no arithmetic on unselected elements), which keeps sweep results bitwise
# equal to per-config runs (tests/test_sweep.py).

# the scatter-free row-set primitive lives once, in transport.py
_set1 = T._set1


def _add1(arr, i, delta):
    """arr.at[i].add(delta) as a one-hot select."""
    return jnp.where(jnp.arange(arr.shape[0]) == i, arr + delta, arr)


def _add2(arr, i, j, delta):
    """arr.at[i, j].add(delta) as a one-hot select."""
    hot = (jnp.arange(arr.shape[0])[:, None] == i) \
        & (jnp.arange(arr.shape[1])[None, :] == j)
    return jnp.where(hot, arr + delta, arr)


def _bulk_push(st, p, mask, times, typ, a0, a1, a2):
    """Insert the masked entries of an event batch, exactly equivalent to
    pushing them one by one in order (the j-th masked entry takes the j-th
    free queue slot, matching the historical first-free-slot search).

    Two implementations sit behind the static ``p.queue_impl`` axis with
    bitwise-identical results (same slot assignment, same drop
    accounting — tests/test_eventq.py):

      "linear"  one vectorized pass over the whole queue (cumsum of the
                free mask + a stable argsort), O(Q log Q) per batch.
                Kept operation-for-operation as the golden anchor.
      "tree"    the tournament-tree path repair (core/eventq.py):
                O(log Q) per entry, only the touched root-to-leaf paths
                are recomputed.
    """
    if p.queue_impl == "tree":
        return EQ.bulk_push(st, mask, times, typ, a0, a1, a2, p.qdepth,
                            p.queue_cap)
    n = times.shape[0]
    free = st["ev_time"] >= INF
    free_rank = jnp.cumsum(free) - 1                 # slot's rank among free
    cnt = mask.sum()
    order = jnp.argsort(jnp.logical_not(mask))       # stable: pushed first
    idx = jnp.minimum(free_rank, n - 1)
    ct = times[order][idx]
    ca = jnp.stack([a0[order][idx], a1[order][idx], a2[order][idx]], -1)
    write = free & (free_rank < cnt)
    st = dict(st)
    st["ev_time"] = jnp.where(write, ct, st["ev_time"])
    st["ev_type"] = jnp.where(write, typ, st["ev_type"])
    st["ev_a"] = jnp.where(write[:, None], ca, st["ev_a"])
    st["dropped"] = st["dropped"] + jnp.maximum(cnt - free.sum(), 0)
    return st


def _maybe_beacon(st, p, g, t):
    """Status broadcast check (Sec 4.2, generalized).  The trigger is the
    statically selected BeaconPolicy (core/policies.py); ``threshold`` is
    the paper's drift rule, and the `k > 1` gate is topology, not policy.

    Delivery is the statically selected Topology (core/transport.py):
    ``ideal`` updates every receiver's view atomically at the global-bus
    grant (the historical behavior, kept operation-for-operation for the
    bitwise golden tests); the non-ideal fabrics enqueue k-1 in-flight
    entries with per-receiver arrival times and deliver via BEACON_RX."""
    load_g = st["loads"][g].sum()
    delta = jnp.abs(load_g - st["last_bcast"][g])
    due = P.beacon_policy(p.policy.beacon)(
        delta, t, st["last_bcast_t"][g], dn_th=p.dn_th, T_b=p.T_b)
    fire = jnp.logical_and(due, p.k > 1)
    if p.faults_on:
        # a dead GMN transmits nothing (alive everywhere: exact no-op)
        fire = jnp.logical_and(fire, st["gmn_alive"][g] > 0)
    st = dict(st)
    if p.topology.kind == "ideal":
        # bus grant: serialize on the global bus; atomic view update.
        # Column .at[] updates, not (k, k) one-hot selects: at the paper
        # point k=256 the one-hot form pays a full 65k-element pass per
        # event; the stored values are identical (element [i, g] becomes
        # fire ? x : old either way), so the frozen goldens still pass
        t_tx = jnp.maximum(t, st["gbus_free"]) + p.c_b
        st["gbus_free"] = jnp.where(fire, t_tx, st["gbus_free"])
        rcv = jnp.arange(p.k) != g
        if p.faults_on:
            # route the atomic update through the mask: receivers behind
            # a down (g, i) link or dead stay stale; the sender's own
            # entry is local bookkeeping and always lands.  With the
            # mask all-up `ok` equals the broadcast `fire`, so the
            # stored values match the no-fault program bitwise.
            dlv = jnp.logical_and(st["link_up"][g] > 0,
                                  st["gmn_alive"] > 0)
            dlv = jnp.logical_or(dlv, jnp.logical_not(rcv))
            ok = jnp.logical_and(fire, dlv)
            lost = jnp.logical_and(fire, jnp.logical_and(
                rcv, jnp.logical_not(dlv)))
            st["msgs_lost"] = st["msgs_lost"] \
                + jnp.sum(lost).astype(jnp.int32)
            ndlv = jnp.sum(jnp.logical_and(rcv, dlv)).astype(jnp.int32)
        else:
            ok = fire
            ndlv = jnp.int32(p.k - 1)
        st["view"] = st["view"].at[:, g].set(
            jnp.where(ok, load_g, st["view"][:, g]))
        st["view_t"] = st["view_t"].at[:, g].set(
            jnp.where(ok, t_tx, st["view_t"][:, g]))
        st["last_bcast"] = jnp.where(fire, _set1(st["last_bcast"], g, load_g),
                                     st["last_bcast"])
        st["last_bcast_t"] = jnp.where(fire,
                                       _set1(st["last_bcast_t"], g, t_tx),
                                       st["last_bcast_t"])
        st["beacons_tx"] = st["beacons_tx"] + jnp.where(fire, 1, 0)
        nrcv = jnp.int32(p.k - 1)
        st["mgmt_msgs"] = st["mgmt_msgs"] + jnp.where(fire, nrcv, 0)
        st["mgmt_latency"] = st["mgmt_latency"] \
            + jnp.where(fire, ndlv.astype(jnp.float32) * (t_tx - t), 0.0)
        return st

    # transport path: per-receiver delivery through the fabric.  The
    # whole fan-out (fabric grants, in-flight matrix, k-entry queue
    # push) is gated behind lax.cond: with `fire` false every masked
    # update below is an exact no-op, so skipping the branch is bitwise
    # invisible — but on CPU (seq mode) the common no-fire event then
    # pays nothing, where the masked code would still run the k-wide
    # push machinery.  Under vmap the cond lowers to a select that
    # executes both branches, which is exactly the pre-gate behavior.
    return jax.lax.cond(fire,
                        lambda s: _beacon_fanout(s, p, g, t, fire, load_g),
                        lambda s: s, st)


def _beacon_fanout(st, p, g, t, fire, load_g):
    """The non-ideal beacon delivery path (only traced when `fire` can be
    true; all updates stay masked by the traced `fire` so the cond's
    both-branch vmap lowering reproduces the masked semantics
    bitwise)."""
    st = dict(st)
    t_tx, t_arr, gbus, lbus = T.beacon_tx(
        p.topology, g, t, fire, gbus=st["gbus_free"], lbus=st["lbus_free"],
        c_b=p.c_b, c_hop=p.c_hop, hops=p.hops, k=p.k)
    st["gbus_free"], st["lbus_free"] = gbus, lbus
    rcv = jnp.arange(p.k) != g                     # receiver mask
    if p.faults_on:
        # best-effort beacons: a delivery whose (g, i) link is down or
        # whose receiver is dead is dropped at injection time and
        # counted in msgs_lost — conservation generalizes to
        # beacons_rx + msgs_lost == (k-1) * beacons_tx.  All-up mask:
        # dlv == rcv, every value below matches the no-fault program.
        dlv = jnp.logical_and(rcv, jnp.logical_and(
            st["link_up"][g] > 0, st["gmn_alive"] > 0))
        lost = jnp.logical_and(fire,
                               jnp.logical_and(rcv, jnp.logical_not(dlv)))
        st["msgs_lost"] = st["msgs_lost"] + jnp.sum(lost).astype(jnp.int32)
    else:
        dlv = rcv
    push = jnp.logical_and(fire, dlv)
    # track the latest pending arrival per (src, rcv); arrivals from one
    # source to one receiver are strictly increasing in send order
    # (c_b > 0 serializes the source), so earlier beacons still in the
    # event queue deliver first and the matrix drains on the last one.
    # Row-indexed .at[] updates, not one-hot selects: this path only
    # compiles off-ideal where k can be 256 (a (k, k) one-hot select is
    # a full 65k-element pass per event there); the stored values are
    # identical, so sweep-vs-run and vmap-vs-seq stay bitwise.
    st["bcn_t"] = st["bcn_t"].at[g].set(
        jnp.where(push, t_arr, st["bcn_t"][g]))
    # the sender's own entry is bookkeeping, not a message: exact at tx
    st["view"] = st["view"].at[g, g].set(
        jnp.where(fire, load_g, st["view"][g, g]))
    st["view_t"] = st["view_t"].at[g, g].set(
        jnp.where(fire, t_tx, st["view_t"][g, g]))
    st["last_bcast"] = jnp.where(fire, _set1(st["last_bcast"], g, load_g),
                                 st["last_bcast"])
    st["last_bcast_t"] = jnp.where(fire, _set1(st["last_bcast_t"], g, t_tx),
                                   st["last_bcast_t"])
    st["beacons_tx"] = st["beacons_tx"] + jnp.where(fire, 1, 0)
    # mgmt_msgs counts messages injected into the fabric (lost ones
    # included); latency and skew only accrue over actual deliveries.
    # No faults: push == fire & rcv == injected, the historical values.
    st["mgmt_msgs"] = st["mgmt_msgs"] \
        + jnp.sum(jnp.logical_and(fire, rcv)).astype(jnp.int32)
    st["mgmt_latency"] = st["mgmt_latency"] \
        + jnp.sum(jnp.where(push, t_arr - t, 0.0))
    spread = jnp.maximum(jnp.max(jnp.where(dlv, t_arr, -INF))
                         - jnp.min(jnp.where(dlv, t_arr, INF)), 0.0)
    st["bcn_skew_sum"] = st["bcn_skew_sum"] + jnp.where(fire, spread, 0.0)
    st["bcn_skew_max"] = jnp.maximum(st["bcn_skew_max"],
                                     jnp.where(fire, spread, 0.0))
    return _bulk_push(st, p, push, t_arr, EV_BEACON_RX,
                      jnp.full((p.k,), g), jnp.arange(p.k),
                      jnp.full((p.k,), load_g))


def _handle_beacon_rx(st, p, t, src, rcv, load):
    """A beacon from GMN src reaches receiver rcv (non-ideal topologies).
    Every delivery applies: per-pair arrivals are strictly increasing in
    send order (c_b > 0 serializes the source), so applying each event's
    payload at its own arrival time is FIFO-correct even when a newer
    beacon from src is already in flight behind it.  The in-flight
    matrix clears only when the LAST tracked arrival lands (`bcn_t == t`),
    which is what lets tests assert it drains to empty."""
    last = st["bcn_t"][src, rcv] == t
    st = dict(st)
    # scalar .at[] updates, not (k, k) one-hot selects: this handler runs
    # once per receiver per beacon (the k-1 fan-out), so at k=256 the
    # one-hot form pays three full 65k-element passes per delivery;
    # the stored values are identical, keeping all bitwise contracts
    st["bcn_t"] = st["bcn_t"].at[src, rcv].set(
        jnp.where(last, INF, st["bcn_t"][src, rcv]))
    st["view"] = st["view"].at[rcv, src].set(load)
    st["view_t"] = st["view_t"].at[rcv, src].set(t)
    st["beacons_rx"] = st["beacons_rx"] + 1
    return st


def _handle_arrive(st, p, t, app, g, _unused, lengths):
    """Stage 1: expand the fork tree at GMN g, fan out LOCAL_SPAWN msgs."""
    k, n = p.k, p.n_childs
    ns = p.ns                                     # cluster targets (static)
    depth = int(np.ceil(np.log2(ns))) if ns > 1 else 0
    share = n // ns
    rem = n - share * ns

    st = dict(st)
    t_eff = t
    if p.faults_on:
        # hot-spare migration: a stimulus addressed to a dead GMN
        # re-homes to the min_search takeover manager through one
        # redirect hop.  Alive everywhere: g unchanged, zero-cost.
        g0 = g
        g = _takeover(st, p, g)
        rehomed = g != g0
        t_eff, gbus_r, lbus_r, lat_r = T.unicast(
            p.topology, g0, g, t, rehomed, gbus=st["gbus_free"],
            lbus=st["lbus_free"], c_b=p.c_b, c_hop=p.c_hop, hops=p.hops)
        st["gbus_free"], st["lbus_free"] = gbus_r, lbus_r
        st["reroutes"] = st["reroutes"] + jnp.where(rehomed, 1, 0)
        st["mgmt_msgs"] = st["mgmt_msgs"] + jnp.where(rehomed, 1, 0)
        st["mgmt_latency"] = st["mgmt_latency"] + lat_r

    # GMN compute: the critical path of the binary fork tree does
    # 2 stage-1 decisions per level (paper Eqn 3: log(n) * Omega_s(k)).
    t_cpu = jnp.maximum(t_eff, st["gmn_free"][g])
    t_tree = t_cpu + 2.0 * depth * p.sel_global
    st["gmn_free"] = _set1(st["gmn_free"], g, t_tree)

    # own cluster count is exact (local data structure); remote via beacons
    own_view = _set1(st["view"][g], g, st["loads"][g].sum())
    # beacon ages feed the staleness-aware policies; own entry always fresh
    age = _set1(jnp.maximum(t_eff - st["view_t"][g], 0.0), g, 0.0)
    # stage-1 cluster choice is the statically selected MappingPolicy
    # (core/policies.py); min_search reproduces the historical inline rule
    # bitwise (min over the view, ties from the GMN's own index)
    pick_cluster = P.mapping_policy(p.policy.mapping)
    rr0 = st["rr_ptr"][g]
    if p.faults_on:
        alive_b = st["gmn_alive"] > 0
        up_row = st["link_up"][g]

    def pick(carry, i):
        view, st_gbus, st_lbus, rr = carry
        if p.faults_on:
            # dead clusters can't accept work: tombstone their view
            # entries so every min-search policy avoids them (the
            # view-agnostic policies may still pick one — the spawn
            # then re-homes at delivery).  The *policy input* is what
            # gets recorded for replay; the carried view stays clean.
            view_pick = jnp.where(alive_b, view, _DEAD_VIEW)
        else:
            view_pick = view
        c = pick_cluster(view_pick, age, g, rr, app, i, k=p.k, T_b=p.T_b)
        cnt = share + jnp.where(i < rem, 1, 0)
        new_view = _add1(view, c, cnt)             # optimistic local bookkeeping
        # task-start message through the fabric (core/transport.py); a
        # self-targeted spawn is a local operation and skips it entirely
        is_remote = c != g
        t_arr, st_gbus, st_lbus, lat = T.unicast(
            p.topology, g, c, t_tree, is_remote, gbus=st_gbus, lbus=st_lbus,
            c_b=p.c_b, c_hop=p.c_hop, hops=p.hops)
        outs = (c, cnt, t_arr, lat, is_remote, view_pick)
        if p.faults_on:
            # reliable task-start: a down (g, c) link detours (never
            # drops); all-up the penalty is exactly 0.0
            pen = T.link_penalty(p.topology, up_row[c], is_remote,
                                 c_b=p.c_b, c_hop=p.c_hop)
            outs = (c, cnt, t_arr + pen, lat + pen, is_remote, view_pick,
                    jnp.logical_and(is_remote, up_row[c] == 0))
        return (new_view, st_gbus, st_lbus, rr + 1), outs

    (new_view, gbus, lbus, rr_out), ys \
        = jax.lax.scan(pick, (own_view, st["gbus_free"], st["lbus_free"],
                              rr0), jnp.arange(ns))
    if p.faults_on:
        cs, cnts, t_arrs, lats, remotes, views, detours = ys
        st["reroutes"] = st["reroutes"] + jnp.sum(detours).astype(jnp.int32)
    else:
        cs, cnts, t_arrs, lats, remotes, views = ys
    st["view"] = _set1(st["view"], g, new_view)
    st["rr_ptr"] = _set1(st["rr_ptr"], g, rr_out)
    st["gbus_free"] = gbus
    st["lbus_free"] = lbus
    st["mgmt_msgs"] = st["mgmt_msgs"] + jnp.sum(remotes).astype(jnp.int32)
    st["mgmt_latency"] = st["mgmt_latency"] + jnp.sum(lats)
    st["mgmt_proc"] = st["mgmt_proc"] + (t_tree - t_eff)
    st["app_remaining"] = _set1(st["app_remaining"], app, n)
    st["app_arrive"] = _set1(st["app_arrive"], app, t)
    if p.record_s1:
        # per-decision inputs/outputs for serving/replay.py: the (possibly
        # stale) view each decision saw, the shared age vector, the chosen
        # cluster, and the round-robin pointer before the fork
        st["dec_view"] = _set1(st["dec_view"], app, views)
        st["dec_age"] = _set1(st["dec_age"], app, age)
        st["dec_choice"] = _set1(st["dec_choice"], app, cs)
        st["dec_rr0"] = _set1(st["dec_rr0"], app, rr0)
        st["dec_t"] = _set1(st["dec_t"], app, t)
        if p.faults_on:
            # the effective decider (post-takeover) for replay
            st["dec_gmn"] = _set1(st["dec_gmn"], app, g)

    return _bulk_push(st, p, jnp.ones((ns,), bool), t_arrs, EV_LOCAL_SPAWN,
                      jnp.full((ns,), app), cs, cnts)


def _spawn_group_bound(p) -> int:
    """Static upper bound on childs per LOCAL_SPAWN group: _handle_arrive
    hands each of its ns targets share or share+1 childs."""
    n, ns = p.n_childs, p.ns
    share = n // ns
    return min(n, share + (1 if n - share * ns > 0 else 0))


def _handle_local_spawn(st, p, t, app, g, cnt, lengths):
    """Stage 2: GMN g maps cnt childs onto its PEs (exact local view).
    Intra-cluster task-starts ride the cluster's local bus — except under
    the ``shared_bus`` topology, where every management message contends
    on the single flat bus."""
    mpk = p.mpk
    n_max = _spawn_group_bound(p)   # static; cnt <= n_max always
    shared = p.topology.kind == "shared_bus"
    st = dict(st)
    t_eff = t
    if p.faults_on:
        # hot-spare migration: a spawn group delivered to a dead GMN
        # re-homes (tasks AND management) to the min_search takeover
        # cluster through one redirect hop
        g0 = g
        g = _takeover(st, p, g)
        rehomed = g != g0
        t_eff, gbus_r, lbus_r, lat_r = T.unicast(
            p.topology, g0, g, t, rehomed, gbus=st["gbus_free"],
            lbus=st["lbus_free"], c_b=p.c_b, c_hop=p.c_hop, hops=p.hops)
        st["gbus_free"], st["lbus_free"] = gbus_r, lbus_r
        st["reroutes"] = st["reroutes"] + jnp.where(rehomed, 1, 0)
        st["mgmt_msgs"] = st["mgmt_msgs"] + jnp.where(rehomed, 1, 0)
        st["mgmt_latency"] = st["mgmt_latency"] + lat_r

    def spawn(carry, i):
        t_cpu, bus, pe_free, loads = carry
        active = i < cnt
        t_cpu = t_cpu + jnp.where(active, p.sel_local, 0.0)
        pe = jnp.argmin(loads)                     # stage-2 min-search
        # task-start over the (local or shared) bus
        t_msg = jnp.maximum(t_cpu, bus) + p.c_b
        bus = jnp.where(active, t_msg, bus)
        start = jnp.maximum(t_msg, pe_free[pe])
        ln = lengths[app, i]
        finish = start + ln
        pe_free = jnp.where(active, _set1(pe_free, pe, finish), pe_free)
        loads = jnp.where(active, _add1(loads, pe, 1), loads)
        return (t_cpu, bus, pe_free, loads), \
            (pe, finish, active, jnp.where(active, t_msg - t_cpu, 0.0))

    t0 = jnp.maximum(t_eff, st["gmn_free"][g])
    bus0 = st["gbus_free"] if shared else st["lbus_free"][g]
    (t_cpu, bus, pe_free, loads), (pes, finishes, actives, lats) = \
        jax.lax.scan(spawn, (t0, bus0, st["pe_free"][g], st["loads"][g]),
                     jnp.arange(n_max))
    st["gmn_free"] = _set1(st["gmn_free"], g, t_cpu)
    if shared:
        st["gbus_free"] = bus
    else:
        st["lbus_free"] = _set1(st["lbus_free"], g, bus)
    st["pe_free"] = _set1(st["pe_free"], g, pe_free)
    st["loads"] = _set1(st["loads"], g, loads)
    st["mgmt_msgs"] = st["mgmt_msgs"] + jnp.sum(actives).astype(jnp.int32)
    st["mgmt_latency"] = st["mgmt_latency"] + jnp.sum(lats)
    st["mgmt_proc"] = st["mgmt_proc"] + (t_cpu - t_eff)

    st = _maybe_beacon(st, p, g, t_cpu)

    return _bulk_push(st, p, actives, finishes, EV_JOIN_EXIT,
                      jnp.full((n_max,), app), jnp.full((n_max,), g), pes)


def _handle_join_exit(st, p, t, app, g, pe, lengths, parent_gmns):
    st = dict(st)
    shared = p.topology.kind == "shared_bus"
    # join-exit message over the bus of the child's cluster (the single
    # shared bus under shared_bus)
    if shared:
        t_msg = jnp.maximum(t, st["gbus_free"]) + p.c_b
        st["gbus_free"] = t_msg
    else:
        t_msg = jnp.maximum(t, st["lbus_free"][g]) + p.c_b
        st["lbus_free"] = _set1(st["lbus_free"], g, t_msg)
    st["loads"] = _add2(st["loads"], g, pe, -1)
    st["mgmt_msgs"] = st["mgmt_msgs"] + 1
    st["mgmt_latency"] = st["mgmt_latency"] + (t_msg - t)
    st = _maybe_beacon(st, p, g, t_msg)
    # the join barrier lives at the application's arrival GMN: remote
    # join-exits forward through the fabric (Tab 2 / Sec 4)
    pg = parent_gmns[app]
    if p.faults_on:
        # the barrier re-homes with its manager (min_search takeover)
        pg0 = pg
        pg = _takeover(st, p, pg)
        st["reroutes"] = st["reroutes"] + jnp.where(pg != pg0, 1, 0)
    remote = pg != g
    t_fwd, gbus, lbus, lat = T.forward(
        p.topology, g, pg, t_msg, remote, gbus=st["gbus_free"],
        lbus=st["lbus_free"], c_b=p.c_b, c_hop=p.c_hop, hops=p.hops)
    if p.faults_on:
        # reliable join-exit forward: a down (g, pg) link detours
        pen = T.link_penalty(p.topology, st["link_up"][g, pg], remote,
                             c_b=p.c_b, c_hop=p.c_hop)
        t_fwd = t_fwd + pen
        lat = lat + pen
        st["reroutes"] = st["reroutes"] + jnp.where(
            jnp.logical_and(remote, st["link_up"][g, pg] == 0), 1, 0)
    st["gbus_free"], st["lbus_free"] = gbus, lbus
    st["mgmt_msgs"] = st["mgmt_msgs"] + jnp.where(remote, 1, 0)
    st["mgmt_latency"] = st["mgmt_latency"] + lat
    t_bar = jnp.maximum(t_fwd, st["gmn_free"][pg]) + p.c_join
    st["mgmt_proc"] = st["mgmt_proc"] + (t_bar - t_fwd)
    st["gmn_free"] = _set1(st["gmn_free"], pg, t_bar)
    rem = st["app_remaining"][app] - 1
    st["app_remaining"] = _set1(st["app_remaining"], app, rem)
    st["app_done"] = jnp.where(
        rem == 0, _set1(st["app_done"], app, t_bar), st["app_done"])
    return st


def _takeover(st, p, g):
    """Hot-spare manager migration (Bosch-style takeover): management
    work addressed to a dead GMN re-homes to the live GMN with the
    least total cluster load — a ``min_search`` over the exact load
    sums, ties to the lowest index.  Alive GMNs keep their own work.
    (If every GMN is dead the work degenerately lands on GMN 0; the
    FaultSpec generators never kill GMN 0, see core/faults.py.)"""
    alive = st["gmn_alive"] > 0
    score = jnp.where(alive, st["loads"].sum(axis=1), _DEAD_VIEW)
    spare = jnp.argmin(score).astype(jnp.int32)
    return jnp.where(alive[g], g, spare)


def _handle_link_down(st, p, t, i, j):
    """LINK_DOWN(i, j): the directed (i, j) fabric link drops.
    Idempotent — a DOWN on an already-down link keeps the original
    outage start (overlapping failures merge, core/faults.py)."""
    st = dict(st)
    was_up = st["link_up"][i, j] > 0
    st["link_down_t"] = st["link_down_t"].at[i, j].set(
        jnp.where(was_up, t, st["link_down_t"][i, j]))
    st["link_up"] = st["link_up"].at[i, j].set(0.0)
    return st


def _handle_link_up(st, p, t, i, j):
    """LINK_UP(i, j): the link heals; the completed outage duration
    lands in the ``downtime`` counter."""
    st = dict(st)
    was_down = st["link_up"][i, j] == 0
    st["downtime"] = st["downtime"] + jnp.where(
        was_down, t - st["link_down_t"][i, j], 0.0)
    st["link_up"] = st["link_up"].at[i, j].set(1.0)
    return st


def _handle_gmn_fail(st, p, t, g):
    """GMN_FAIL(g): manager g dies.  Pending work re-homes lazily — each
    queued event addressed to g runs ``_takeover`` when it pops, so no
    queue surgery is needed and the re-home pays its redirect cost at
    the time the work actually moves."""
    st = dict(st)
    was_alive = st["gmn_alive"][g] > 0
    st["gmn_down_t"] = st["gmn_down_t"].at[g].set(
        jnp.where(was_alive, t, st["gmn_down_t"][g]))
    st["gmn_alive"] = st["gmn_alive"].at[g].set(0.0)
    return st


def _handle_gmn_heal(st, p, t, g):
    """GMN_HEAL(g): manager g recovers (its view ages stay stale until
    fresh beacons arrive, which the staleness policies already price)."""
    st = dict(st)
    was_dead = st["gmn_alive"][g] == 0
    st["downtime"] = st["downtime"] + jnp.where(
        was_dead, t - st["gmn_down_t"][g], 0.0)
    st["gmn_alive"] = st["gmn_alive"].at[g].set(1.0)
    return st


def _push_faults(st, p, f, sim_len):
    """Seed the event queue with the fault schedule, grouped by kind in
    LINK_DOWN, LINK_UP, GMN_FAIL, GMN_HEAL order (after the arrivals) —
    a deterministic slot assignment, so same-tick ties between fault
    and work events break identically on every run and queue impl."""
    if f.times.shape[0] == 0:
        return st
    live = f.times < sim_len
    zeros = jnp.zeros_like(f.a0)
    for kind in range(4):
        st = _bulk_push(st, p, jnp.logical_and(live, f.kinds == kind),
                        f.times, EV_LINK_DOWN + kind, f.a0, f.a1, zeros)
    return st


def simulate(shape: SimShape, knobs: SimKnobs, arrivals, arrival_gmns,
             lengths, sim_len, policy: SimPolicy = DEFAULT_POLICY,
             topology: Topology = DEFAULT_TOPOLOGY,
             faults: FLT.FaultSchedule | None = None):
    """Traceable core: static ``shape``, ``policy`` and ``topology``,
    traced everything else.  This is what ``repro.core.sweep`` vmaps over
    knob/workload batches (one XLA program per (shape, policy, topology)
    triple)."""
    p = _Ctx(shape, knobs, policy, topology, faults_on=faults is not None)
    st = make_state(p)

    n_apps = arrivals.shape[0]
    st = _bulk_push(st, p, arrivals < sim_len, arrivals, EV_ARRIVE,
                    jnp.arange(n_apps), arrival_gmns,
                    jnp.zeros((n_apps,), jnp.int32))
    if faults is not None:
        st = _push_faults(st, p, faults, sim_len)

    if p.queue_impl == "tree":
        def cond(st):
            return EQ.peek_time(st) < INF              # tree root, O(1)
    else:
        def cond(st):
            return st["ev_time"].min() < INF           # O(Q) linear scan

    branches = [
        lambda s, t, a: _handle_arrive(s, p, t, a[0], a[1], a[2], lengths),
        lambda s, t, a: _handle_local_spawn(s, p, t, a[0], a[1], a[2],
                                            lengths),
        lambda s, t, a: _handle_join_exit(s, p, t, a[0], a[1], a[2], lengths,
                                          arrival_gmns),
    ]
    if topology.kind != "ideal" or p.faults_on:
        # BEACON_RX exists only on the non-ideal fabrics; the ideal
        # program keeps its historical 3-branch switch (under vmap every
        # branch executes each step, so the extra branch must not tax the
        # golden configuration).  With faults the branch is present even
        # under ideal so the fault event types stay fixed at 4..7.
        branches.append(
            lambda s, t, a: _handle_beacon_rx(s, p, t, a[0], a[1], a[2]))
    if p.faults_on:
        branches += [
            lambda s, t, a: _handle_link_down(s, p, t, a[0], a[1]),
            lambda s, t, a: _handle_link_up(s, p, t, a[0], a[1]),
            lambda s, t, a: _handle_gmn_fail(s, p, t, a[0]),
            lambda s, t, a: _handle_gmn_heal(s, p, t, a[0]),
        ]

    def body(st):
        if p.queue_impl == "tree":
            # O(log Q): the tree root IS the event (time, type, args
            # included) — one row read plus one path repair
            st, t, slot, typ, a = EQ.pop(st, p.qdepth)
        else:
            slot = jnp.argmin(st["ev_time"])              # O(Q) per event
            t = st["ev_time"][slot]
            typ = st["ev_type"][slot]
            a = st["ev_a"][slot]
            st = dict(st)
            st["ev_time"] = _set1(st["ev_time"], slot, INF)  # recycle slot
        st = dict(st)
        st["events_processed"] = st["events_processed"] + 1
        st = jax.lax.switch(typ, [lambda s, b=b: b(s, t, a)
                                  for b in branches], st)
        return st

    return jax.lax.while_loop(cond, body, st)


_run = jax.jit(simulate, static_argnums=(0, 6, 7))


def run(p: SimParams, arrivals, arrival_gmns, lengths, sim_len: float = 1e7,
        faults=None):
    """arrivals (A,) f32 times (INF = unused); arrival_gmns (A,) i32;
    lengths (A, n_childs) f32 child task lengths.

    Returns final state dict (response times = app_done - app_arrive).
    Compiles once per ``(p.shape, p.policy, p.topo)``; the numeric knobs
    (c_b, c_s, c_join, dn_th, T_b, c_hop) and sim_len are traced, so
    threshold/cost/period sweeps re-use the compiled program.

    ``faults`` is an optional ``FaultSpec`` or prebuilt ``FaultSchedule``
    (repro.core.faults).  The schedule is a *traced* pytree: swapping
    schedules of the same length (a fault seed/intensity grid) re-uses
    the compiled fault-aware program; only passing None vs a schedule —
    or changing the schedule length — compiles a new one.
    """
    return _run(p.shape, p.knobs,
                jnp.asarray(arrivals, jnp.float32),
                jnp.asarray(arrival_gmns, jnp.int32),
                jnp.asarray(lengths, jnp.float32),
                jnp.float32(sim_len), p.policy, p.topo,
                FLT.as_schedule(faults, p.k, sim_len))


def compile_cache_size() -> int:
    """Number of XLA programs compiled for ``run`` (one per
    (SimShape, SimPolicy, Topology) triple).
    Relies on jit's private cache introspection; returns 0 if a future
    JAX drops it (degrading compile-count reporting, not simulation)."""
    counter = getattr(_run, "_cache_size", None)
    return counter() if callable(counter) else 0


# --------------------------------------------------------------------------
# Metrics — single implementation in repro.core.metrics, re-exported here
# (and from repro.core.sweep); shape-polymorphic over any leading batch
# axes.  speedup(state, lengths) returns the masked mean per point; the
# completion count is `response_times(state)[1].sum()`.
# --------------------------------------------------------------------------

from repro.core.metrics import (beacons, beacons_rx,  # noqa: E402,F401
                                mean_response, mgmt_latency, mgmt_msgs,
                                mgmt_proc, response_times, speedup)
