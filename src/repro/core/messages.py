"""Message protocol (paper Table 1 / Table 2).

Each message is a fixed header + 32-bit data words:

    | type | src | dst | prio | flag | data... |

Types cover the system calls (rcsv-spwn/exit, join-init/free/wait/exit),
task-start and status-beacon.  Messages pack into int32 vectors so both the
TLM simulator and the serving engine can queue them in fixed-shape arrays.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Sequence

import numpy as np


class MsgType(enum.IntEnum):
    RCSV_SPWN = 0
    RCSV_EXIT = 1
    JOIN_INIT = 2
    JOIN_FREE = 3
    JOIN_WAIT = 4
    JOIN_EXIT = 5
    TASK_START = 6
    STATUS_BEACON = 7


BROADCAST = -1
HEADER_WORDS = 5
MAX_DATA_WORDS = 3
MSG_WORDS = HEADER_WORDS + MAX_DATA_WORDS


@dataclass(frozen=True)
class Message:
    type: MsgType
    src: int
    dst: int                      # BROADCAST for beacons
    prio: int = 0
    flag: int = 0                 # broadcast flag
    data: Sequence[int] = field(default_factory=tuple)

    def pack(self) -> np.ndarray:
        w = np.zeros(MSG_WORDS, np.int32)
        w[0] = int(self.type)
        w[1] = self.src
        w[2] = self.dst
        w[3] = self.prio
        w[4] = self.flag
        for i, d in enumerate(self.data[:MAX_DATA_WORDS]):
            w[HEADER_WORDS + i] = d
        return w

    @staticmethod
    def unpack(w) -> "Message":
        w = np.asarray(w, np.int32)
        return Message(MsgType(int(w[0])), int(w[1]), int(w[2]), int(w[3]),
                       int(w[4]), tuple(int(x) for x in w[HEADER_WORDS:]))


def beacon(src: int, load: int, prio: int = 0) -> Message:
    return Message(MsgType.STATUS_BEACON, src, BROADCAST, prio, 1, (load,))


def task_start(src: int, dst: int, tcb_addr: int, stack_ptr: int,
               prio: int = 0) -> Message:
    return Message(MsgType.TASK_START, src, dst, prio, 0, (tcb_addr, stack_ptr))


def join_exit(src: int, dst: int, barrier_addr: int) -> Message:
    return Message(MsgType.JOIN_EXIT, src, dst, 0, 0, (barrier_addr,))
