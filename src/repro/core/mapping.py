"""Two-stage hierarchical task mapping (paper Sec 4.1) — framework-facing API.

The batch path (`map_one`/`map_batch`) routes through the
`kernels/hier_minsearch` Pallas kernel — compiled on TPU, interpret mode
elsewhere — via `kernels.ops.assign_tasks`; the host-side stage-1 choice
(`stage1_pick`) delegates to the pluggable policy core
(`core/policies.py`), which is the same logic the TLM simulator traces
and the serving engine's schedulers call per request.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.core import policies as P
from repro.kernels import ops


@dataclass
class MapperState:
    """k clusters x m/k units; `view` holds beacon-synced remote summaries."""
    loads: jnp.ndarray            # (k, m_per_k) exact local loads
    view: jnp.ndarray             # (k,) per-cluster summaries (possibly stale)

    @classmethod
    def create(cls, k: int, m_per_k: int):
        return cls(loads=jnp.zeros((k, m_per_k), jnp.float32),
                   view=jnp.zeros((k,), jnp.float32))


def map_one(state: MapperState, cost: float = 1.0):
    """One two-stage decision: returns ((cluster, unit), new state)."""
    assigns, new_loads = ops.assign_tasks(
        state.loads, jnp.asarray([cost], jnp.float32))
    c, u = int(assigns[0, 0]), int(assigns[0, 1])
    return (c, u), MapperState(loads=new_loads,
                               view=new_loads.sum(axis=1))


def map_batch(state: MapperState, costs):
    """Map a batch of tasks sequentially (the paper's FCFS order)."""
    costs = jnp.asarray(costs, jnp.float32)
    assigns, new_loads = ops.assign_tasks(state.loads, costs)
    return assigns, MapperState(loads=new_loads, view=new_loads.sum(axis=1))


def stage1_pick(view, start: int = 0, *, policy: str = "min_search",
                age=None, rr: int = 0, salt: int = 0,
                T_b: float = float("inf")):
    """Stage-1 cluster choice over (stale) per-cluster summaries via the
    selected MappingPolicy (default: the paper's min-search, tie-broken
    starting at `start`, the searching node's own index)."""
    return P.host_pick(policy, np.asarray(view), age, start, rr, salt,
                       T_b=T_b)


def fork_tree_targets(n_tasks: int, k: int, m_per_k: int):
    """Recursive-spawn stop rule (Sec 4.1): number of cluster targets and
    fork-tree depth for n_tasks childs."""
    ns = min(k, max(1, -(-n_tasks // m_per_k)))
    depth = int(np.ceil(np.log2(ns))) if ns > 1 else 0
    return ns, depth
