"""Two-stage hierarchical task mapping (paper Sec 4.1) — framework-facing API.

The TLM simulator inlines this logic for tick accounting; the serving engine
and launcher consume it through this module.  `assign_tasks` dispatches to
the Pallas kernel on TPU (kernels/hier_minsearch.py).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


@dataclass
class MapperState:
    """k clusters x m/k units; `view` holds beacon-synced remote summaries."""
    loads: jnp.ndarray            # (k, m_per_k) exact local loads
    view: jnp.ndarray             # (k,) per-cluster summaries (possibly stale)

    @classmethod
    def create(cls, k: int, m_per_k: int):
        return cls(loads=jnp.zeros((k, m_per_k), jnp.float32),
                   view=jnp.zeros((k,), jnp.float32))


def map_one(state: MapperState, cost: float = 1.0):
    """One two-stage decision: returns ((cluster, unit), new state)."""
    assigns, new_loads = ops.assign_tasks(
        state.loads, jnp.asarray([cost], jnp.float32))
    c, u = int(assigns[0, 0]), int(assigns[0, 1])
    return (c, u), MapperState(loads=new_loads,
                               view=new_loads.sum(axis=1))


def map_batch(state: MapperState, costs):
    """Map a batch of tasks sequentially (the paper's FCFS order)."""
    costs = jnp.asarray(costs, jnp.float32)
    assigns, new_loads = ops.assign_tasks(state.loads, costs)
    return assigns, MapperState(loads=new_loads, view=new_loads.sum(axis=1))


def stage1_pick(view, start: int = 0):
    """Cluster choice by min-search over (stale) per-cluster summaries,
    tie-broken starting at `start` (the searching node's own index)."""
    k = view.shape[0]
    perm = (np.arange(k) + start) % k
    return int(perm[int(np.argmin(np.asarray(view)[perm]))])


def fork_tree_targets(n_tasks: int, k: int, m_per_k: int):
    """Recursive-spawn stop rule (Sec 4.1): number of cluster targets and
    fork-tree depth for n_tasks childs."""
    ns = min(k, max(1, -(-n_tasks // m_per_k)))
    depth = int(np.ceil(np.log2(ns))) if ns > 1 else 0
    return ns, depth
