"""Batched design-space sweeps over the TLM simulator (paper Sec 5).

The paper's evaluation is a design-space exploration: sweep the beacon
threshold ``dn_th`` and the cost coefficients across cluster counts and
workload seeds (Figs 2-3, Table 5).  ``sim.run`` compiles once per
``SimShape`` (m, k, n_childs, queue_cap, max_apps, queue_impl); this
module goes one step further and runs a whole grid of knob configs x
workload seeds in a single compiled program:

    p = SimParams(m=256, k=16)
    knobs = knob_batch(dn_th=(1, 2, 4, 8, 16, 32))        # B = 6 configs
    wl = W.interference_batch(p, seeds=(1, 2), sim_len=4e6)  # S = 2 seeds
    st = sweep(p.shape, knobs, wl, sim_len=4e6)
    beacons(st)          # (6, 2) int array

Every leaf of the returned state dict carries leading axes ``(B, S)``:
axis 0 indexes the knob config, axis 1 the workload.  Results are bitwise
identical to per-config ``sim.run`` calls (tests/test_sweep.py): ``vmap``
batches the very same traced computation, it does not approximate it.

Two execution strategies sit behind one API (see ``sweep``'s ``mode``):
"vmap" runs the grid as one batched XLA program (the accelerator path —
the inner ``lax.while_loop`` batches as run-until-all-lanes-done with
masked updates), "seq" replays the single-config program warm (the CPU
path — zero recompiles across the grid).  Either way the design-space
grid costs one compilation per (m, k) shape instead of one per point.

Sweeping the *static* axes (shapes, policies, topologies, queue impls)
lives one level up in :mod:`repro.core.experiment` (DESIGN.md §12): an
``ExperimentSpec`` composes every axis declaratively and its planner
calls back into this module's jitted programs, so results stay bitwise
identical.  ``sweep_policies``/``sweep_topologies`` below are the
deprecated pre-spec shims.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policies import DEFAULT_POLICY, SimPolicy, policy_grid
# batched metrics live in repro.core.metrics (single implementation,
# re-exported here and from repro.core.sim — tests/test_experiment.py
# asserts both import paths resolve to the same functions)
from repro.core.metrics import (beacons, beacons_rx, mean_response,
                                mgmt_latency, mgmt_msgs, mgmt_proc,
                                response_times, speedup)
from repro.core.sim import (SimKnobs, SimParams, SimShape, _run,
                            compile_cache_size, simulate)
from repro.core.transport import DEFAULT_TOPOLOGY, Topology, topology_grid

__all__ = ["knob_batch", "knob_product", "sweep", "sweep_policies",
           "sweep_topologies", "policy_grid", "topology_grid", "cache_size",
           "response_times", "speedup", "mean_response", "beacons",
           "beacons_rx", "mgmt_msgs", "mgmt_latency", "mgmt_proc"]


def knob_batch(*, c_b=8.0, c_s=8.0, c_join=8.0, dn_th=4,
               T_b=1000.0, c_hop=2.0) -> SimKnobs:
    """Build a batch of B knob configs.  Each argument is a scalar
    (broadcast) or a length-B sequence; sequences must agree on B."""
    vals = {"c_b": c_b, "c_s": c_s, "c_join": c_join, "dn_th": dn_th,
            "T_b": T_b, "c_hop": c_hop}
    sizes = {name: len(v) for name, v in vals.items()
             if np.ndim(v) == 1}
    if len(set(sizes.values())) > 1:
        raise ValueError(f"knob sequences disagree on batch size: {sizes}")
    b = next(iter(sizes.values()), 1)
    def col(v, dtype):
        a = np.asarray(v, dtype)
        return jnp.asarray(np.broadcast_to(a, (b,)))
    return SimKnobs(c_b=col(vals["c_b"], np.float32),
                    c_s=col(vals["c_s"], np.float32),
                    c_join=col(vals["c_join"], np.float32),
                    dn_th=col(vals["dn_th"], np.int32),
                    T_b=col(vals["T_b"], np.float32),
                    c_hop=col(vals["c_hop"], np.float32))


def knob_product(*, c_b=(8.0,), c_s=(8.0,), c_join=(8.0,), dn_th=(4,),
                 T_b=(1000.0,), c_hop=(2.0,)) -> SimKnobs:
    """Cartesian product of knob axes, flattened to one batch axis in
    ``itertools.product`` order (c_b outermost, c_hop innermost)."""
    rows = list(itertools.product(np.atleast_1d(c_b), np.atleast_1d(c_s),
                                  np.atleast_1d(c_join),
                                  np.atleast_1d(dn_th), np.atleast_1d(T_b),
                                  np.atleast_1d(c_hop)))
    cb, cs, cj, th, tb, ch = (np.asarray(col) for col in zip(*rows))
    return SimKnobs(c_b=jnp.asarray(cb, jnp.float32),
                    c_s=jnp.asarray(cs, jnp.float32),
                    c_join=jnp.asarray(cj, jnp.float32),
                    dn_th=jnp.asarray(th, jnp.int32),
                    T_b=jnp.asarray(tb, jnp.float32),
                    c_hop=jnp.asarray(ch, jnp.float32))


@functools.partial(jax.jit, static_argnums=(0, 6, 7))
def _sweep(shape, knobs, arrivals, gmns, lengths, sim_len,
           policy=DEFAULT_POLICY, topology=DEFAULT_TOPOLOGY, faults=None):
    # the fault schedule (repro.core.faults) is shared across all lanes:
    # closed over rather than vmapped, like sim_len
    def per_workload(a, g, l):
        return jax.vmap(
            lambda kn: simulate(shape, kn, a, g, l, sim_len, policy,
                                topology, faults))(knobs)
    # out_axes=1: knob-config axis stays leading, workload axis second
    return jax.vmap(per_workload, in_axes=0, out_axes=1)(
        arrivals, gmns, lengths)


def sweep(shape, knobs: SimKnobs, workload, sim_len: float = 1e7,
          mode: str = "auto", policy: SimPolicy | None = None,
          topology: Topology | None = None,
          queue_impl: str | None = None, faults=None):
    """Run B knob configs x S workloads with one compilation per
    (shape, policy, topology).

    shape     SimShape, or a full SimParams — then ALL of its static
              axes round-trip: `.shape` (incl. queue_impl), `.policy`
              and `.topo` are taken wherever the corresponding kwarg is
              left unset (explicit kwargs still win).
    knobs     SimKnobs with leading axis (B,) — see knob_batch/knob_product.
    workload  (arrivals (S, A), arrival_gmns (S, A), lengths (S, A, n))
              as produced by workloads.interference_batch / *_grid.
    policy    SimPolicy (mapping x beacon, core/policies.py).  Static —
              every combination is its own XLA program; sweep the policy
              axis declaratively with ``experiment.ExperimentSpec``
              (DESIGN.md §12).
    topology  Topology (fabric model, core/transport.py).  Also static —
              sweep the fabric axis via ``ExperimentSpec`` too; the
              numeric transport knobs (c_b, c_hop) stay traced.
    mode      execution strategy; results are bitwise identical across
              modes (tests/test_sweep.py):
              - "vmap": the whole grid is ONE batched XLA program (one
                compile per (shape, policy, topology, B, S)).  Wins on
                accelerators where lanes vectorize; on CPU the batched
                while-loop pays for every event handler in every lane
                each step.
              - "seq": warm re-runs of the single-config program (one
                compile per (shape, policy, topology), zero recompiles
                across the grid) — the fast path on CPU.
              - "auto" (default): "seq" on CPU, "vmap" elsewhere.
    queue_impl  event-queue structure override (core/eventq.py,
              DESIGN.md §11): "linear" or "tree".  Part of the static
              shape; None (default) keeps ``shape.queue_impl``.  Results
              are bitwise identical across impls — "tree" replaces the
              O(queue_cap) argmin per event with O(log queue_cap) tree
              repairs, the difference is wall-clock only.
    faults    optional FaultSpec or prebuilt FaultSchedule
              (repro.core.faults, DESIGN.md §13), shared across every
              (knob, workload) lane.  The schedule is traced: a grid of
              fault seeds/intensities of the same length re-uses the
              compiled fault-aware program in both modes (zero
              recompiles, the fault_frontier claim gate).

    Returns the final-state dict with every leaf batched to (B, S, ...).
    """
    if isinstance(shape, SimParams):
        # round-trip every static axis of a full SimParams: policy and
        # topology used to be silently dropped here (ISSUE 5 satellite;
        # regression test in tests/test_sweep.py)
        if policy is None:
            policy = shape.policy
        if topology is None:
            topology = shape.topo
        shape = shape.shape
    if policy is None:
        policy = DEFAULT_POLICY
    if topology is None:
        topology = DEFAULT_TOPOLOGY
    if queue_impl is not None and queue_impl != shape.queue_impl:
        shape = dataclasses.replace(shape, queue_impl=queue_impl)
    arrivals, gmns, lengths = workload
    arrivals = jnp.asarray(arrivals, jnp.float32)
    gmns = jnp.asarray(gmns, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.float32)
    if arrivals.ndim != 2 or lengths.ndim != 3:
        raise ValueError("workload arrays need a leading seed axis (S,); "
                         "use workloads.interference_batch")
    if knobs.dn_th.ndim != 1:
        raise ValueError("knobs need a leading batch axis (B,); "
                         "use knob_batch/knob_product")
    if isinstance(topology, str):
        topology = Topology(topology)
    from repro.core.faults import as_schedule
    faults = as_schedule(faults, shape.k, sim_len)
    if mode == "auto":
        mode = "seq" if jax.default_backend() == "cpu" else "vmap"
    if mode == "vmap":
        return _sweep(shape, knobs, arrivals, gmns, lengths,
                      jnp.float32(sim_len), policy, topology, faults)
    if mode != "seq":
        raise ValueError(f"unknown sweep mode: {mode!r}")
    b, s = knobs.dn_th.shape[0], arrivals.shape[0]
    sl = jnp.float32(sim_len)
    outs = [_run(shape, SimKnobs(*(leaf[i] for leaf in knobs)),
                 arrivals[j], gmns[j], lengths[j], sl, policy, topology,
                 faults)
            for i in range(b) for j in range(s)]
    return jax.tree.map(
        lambda *leaves: jnp.stack(leaves).reshape((b, s) + leaves[0].shape),
        *outs)


def sweep_policies(shape, knobs: SimKnobs, workload, policies=None,
                   sim_len: float = 1e7, mode: str = "auto",
                   topology: Topology = DEFAULT_TOPOLOGY) -> dict:
    """DEPRECATED shim over :mod:`repro.core.experiment` — express the
    policy axis declaratively instead::

        ExperimentSpec(shapes=(shape,), policies=policies,
                       knobs=knobs, workloads=(WorkloadSpec.raw(wl),),
                       sim_len=sim_len).run()

    Returns the historical {(mapping, beacon): (B, S, ...) state dict}
    mapping, bitwise identical (the spec path runs the same programs).
    """
    warnings.warn("sweep_policies is deprecated; use "
                  "repro.core.experiment.ExperimentSpec (DESIGN.md §12)",
                  DeprecationWarning, stacklevel=2)
    from repro.core.experiment import ExperimentSpec, WorkloadSpec
    policies = tuple(policies) if policies is not None \
        else tuple(policy_grid())
    frame = ExperimentSpec(
        shapes=(shape,), policies=policies,
        topologies=(Topology(topology) if isinstance(topology, str)
                    else topology,),
        knobs=knobs, workloads=(WorkloadSpec.raw(workload),),
        sim_len=sim_len, mode=mode).run()
    return {(pol.mapping, pol.beacon):
            frame.state(mapping=pol.mapping, beacon=pol.beacon)
            for pol in policies}


def sweep_topologies(shape, knobs: SimKnobs, workload, topologies=None,
                     sim_len: float = 1e7, mode: str = "auto",
                     policy: SimPolicy = DEFAULT_POLICY) -> dict:
    """DEPRECATED shim over :mod:`repro.core.experiment` — express the
    fabric axis declaratively instead::

        ExperimentSpec(shapes=(shape,), topologies=topologies,
                       knobs=knobs, workloads=(WorkloadSpec.raw(wl),),
                       sim_len=sim_len).run()

    Returns the historical {kind: (B, S, ...) state dict} mapping,
    bitwise identical (the spec path runs the same programs).
    """
    warnings.warn("sweep_topologies is deprecated; use "
                  "repro.core.experiment.ExperimentSpec (DESIGN.md §12)",
                  DeprecationWarning, stacklevel=2)
    from repro.core.experiment import ExperimentSpec, WorkloadSpec
    if topologies is None:
        topologies = topology_grid()
    topologies = [Topology(tp) if isinstance(tp, str) else tp
                  for tp in topologies]
    frame = ExperimentSpec(
        shapes=(shape,), policies=(policy,), topologies=tuple(topologies),
        knobs=knobs, workloads=(WorkloadSpec.raw(workload),),
        sim_len=sim_len, mode=mode).run()
    return {tp.kind: frame.state(topology=tp.kind) for tp in topologies}


def cache_size() -> int:
    """Total XLA programs compiled for sweeping: one per
    (SimShape, SimPolicy, B, S) in vmap mode plus one per
    (SimShape, SimPolicy) in seq mode.  Returns only the seq count if a
    future JAX drops jit's private cache introspection."""
    counter = getattr(_sweep, "_cache_size", None)
    vmap_count = counter() if callable(counter) else 0
    return vmap_count + compile_cache_size()


# Batched metrics (response_times, mean_response, speedup, beacons,
# beacons_rx, mgmt_*) are imported from repro.core.metrics at the top of
# this module — one implementation, re-exported here for compatibility.
