"""Tournament-tree event queue for the TLM simulator (DESIGN.md §11).

The simulator's hot loop pops the earliest pending event once per
iteration.  The historical implementation (``queue_impl="linear"``) finds
it with ``jnp.argmin`` over the whole ``(queue_cap,)`` ``ev_time`` array,
checks termination with a queue-wide ``min``, and inserts batches with a
queue-wide stable ``argsort`` — O(Q)-to-O(Q log Q) work per event, which
ROADMAP.md names as the blocker for the paper's m=256/k=256 distributed
configuration on non-ideal fabrics (every beacon there fans out into k-1
BEACON_RX events, so Q must be large exactly where the per-event scan
hurts most).

This module replaces those scans with a **static-depth tournament tree**,
a segmented pairwise-min reduction over the event times.  The whole
queue lives in ONE ``(2*Qp + S, 6)`` f32 array ``evq_tree`` (Qp =
2**depth >= queue_cap; S = per-segment free counters):

  rows 1..2Qp     the implicit-heap tournament tree (node 0 unused,
                  root at 1, node n's children at 2n and 2n+1, leaf for
                  queue slot j at Qp + j).  A row is the full record of
                  the minimal event in the node's subtree:
                  [time, slot, ev_type, a0, a1, a2] — each pairwise
                  reduction copies the winning child's row wholesale, so
                  the ROOT row is the next event including its payload.
                  Slot indices and payloads are small exact integers in
                  f32 (queue_cap is capped at 2**24, event arguments are
                  app/cluster/PE indices and counts far below it).
  rows 2Qp..      per-ALLOC_SEG-slot free counters (column 0).

One array is the point, not a convenience: XLA:CPU updates a chain of
gathers-then-scatters on a single buffer in place, but a second scatter
whose indices derive from a read of another array forces a full copy of
the big buffer per event (measured ~60-100 us at Q=32768 — more than
the whole pop).  Fusing payloads and counters into the tree keeps every
per-event write on one buffer:

  cond/peek  read the root row: O(1) instead of the O(Q) ``min``; pop
             needs no payload gathers at all.
  pop        the root IS (t, slot, type, args); clear the leaf and
             repair its root path with one sibling gather, an unrolled
             running-min register chain, and one path scatter —
             O(log Q).
  bulk push  allocate slots from the free counters: a cumsum +
             ``searchsorted`` over Q/64 segments finds each entry's
             segment, a gathered (n, 64) window of leaf times finds the
             exact slot — so the j-th masked entry takes the j-th
             lowest free slot, bitwise the linear impl's
             first-free-slot rule.  Leaf writes then repair the touched
             paths **level-parallel**: per level one (n, 2, 6)
             child-pair gather + one (n, 6) row scatter (duplicate
             parents write identical rows, so scatter order is
             irrelevant), O(n + log Q) small ops per batch instead of
             the queue-wide argsort.

Everything is fixed-shape with no data-dependent control flow: the depth
is a static Python int (loops unroll at trace time), updates are
``.at[].set`` writes with traced indices (out-of-range lanes dropped via
``mode="drop"``), and repairs are idempotent, so masked entries simply
re-write unchanged rows.  That keeps the structure vmap-able and
scan-friendly — ``sweep.py``'s "vmap" and "seq" modes stay bitwise
identical under ``queue_impl="tree"`` (tests/test_eventq.py), and the
whole queue state is one ordinary state-dict leaf.

Tie-breaking contract: ``jnp.argmin`` returns the LOWEST index among
equal minima, and same-timestamp events must pop in identical order
under both impls, so every pairwise reduction here takes the left child
on ties (``l <= r``) — the left subtree holds the lower slot indices,
hence the root is the lowest-index argmin at every level
(tests/test_eventq.py::test_pop_slot_matches_argmin_under_ties).  The
pop repair reproduces the same rule from the sibling side: the path
node wins a tie iff it is the left child.

``repro.core.sim`` selects the implementation through the static
``queue_impl`` axis on ``SimShape`` (one XLA program per value):
``"linear"`` keeps the historical code operation-for-operation — the
golden anchor every frozen sha in tests/test_sweep.py gates — and
``"tree"`` routes pop/push through this module with bitwise-identical
results.
"""
from __future__ import annotations

import math

import jax.numpy as jnp

INF = jnp.float32(1e18)

QUEUE_IMPLS = ("linear", "tree")

# Free-slot accounting granularity: one counter per ALLOC_SEG queue
# slots.  64 keeps the per-push cumsum at Q/64 elements (512 at the
# paper-scale Q=32768) while the within-segment search stays one small
# (n, 64) gathered window.
ALLOC_SEG = 64

# Queue slots (and event payloads) are stored as exact small integers in
# the tree's f32 columns.
MAX_QUEUE_CAP = 1 << 24

# Row layout: [time, slot, ev_type, a0, a1, a2].
ROW_W = 6


def tree_depth(queue_cap: int) -> int:
    """Static tree depth: the smallest d with 2**d >= queue_cap."""
    return max(1, math.ceil(math.log2(max(queue_cap, 2))))


def leaf_count(queue_cap: int) -> int:
    """Padded leaf count Qp = 2**depth (slots >= queue_cap stay INF
    forever, so the padding is invisible to the simulation)."""
    return 1 << tree_depth(queue_cap)


def seg_count(queue_cap: int) -> int:
    """Number of ALLOC_SEG-slot segments covering the queue."""
    return -(-queue_cap // ALLOC_SEG)


# --------------------------------------------------------------------------
# Full rebuilds (vectorized, O(Q)): initial state + the reference the
# incremental path repairs are property-tested against.
# --------------------------------------------------------------------------

def build_tree(times, typ=None, a=None):
    """(queue_cap,) event times (+ optional payloads: ``typ`` (Q,) and
    ``a`` (Q, 3)) -> the full ``evq_tree`` array: pairwise winner-row
    reduction with lowest-index tie-breaking, free counters appended."""
    q = times.shape[0]
    if q > MAX_QUEUE_CAP:
        raise ValueError(f"queue_cap {q} exceeds the exact-f32 slot-index "
                         f"range ({MAX_QUEUE_CAP})")
    qp = leaf_count(q)
    times = jnp.asarray(times, jnp.float32)
    typ = jnp.zeros((q,), jnp.float32) if typ is None \
        else jnp.asarray(typ, jnp.float32)
    a = jnp.zeros((q, 3), jnp.float32) if a is None \
        else jnp.asarray(a, jnp.float32)
    leaves = jnp.concatenate([
        jnp.stack([times, jnp.arange(q, dtype=jnp.float32), typ], -1),
        a], axis=-1)
    pad = jnp.concatenate([
        jnp.stack([jnp.full((qp - q,), INF),
                   jnp.arange(q, qp, dtype=jnp.float32),
                   jnp.zeros((qp - q,))], -1),
        jnp.zeros((qp - q, 3))], axis=-1)
    rows = jnp.concatenate([leaves, pad])
    levels = [rows]
    for _ in range(tree_depth(q)):
        left, right = rows[0::2], rows[1::2]
        take_l = left[:, 0] <= right[:, 0]   # ties -> left = lower slot
        rows = jnp.where(take_l[:, None], left, right)
        levels.append(rows)
    free = jnp.zeros((seg_count(q), ROW_W))
    free = free.at[:, 0].set(build_freecnt(times >= INF).astype(jnp.float32))
    return jnp.concatenate([jnp.zeros((1, ROW_W))] + levels[::-1] + [free])


def build_freecnt(free_mask):
    """(queue_cap,) bool free mask -> (S,) i32 per-segment free-slot
    counts (the last segment may cover fewer than ALLOC_SEG slots)."""
    q = free_mask.shape[0]
    s = seg_count(q)
    pad = jnp.zeros((s * ALLOC_SEG - q,), bool)
    return jnp.concatenate([jnp.asarray(free_mask, bool), pad]) \
        .reshape(s, ALLOC_SEG).sum(axis=1).astype(jnp.int32)


def queue_state(queue_cap: int) -> dict:
    """The state-dict leaf of ``queue_impl="tree"`` (an empty queue: all
    times INF, all slots free).  The linear impl's ``ev_time`` /
    ``ev_type`` / ``ev_a`` arrays do not exist in tree mode — times and
    payloads live in the tree rows (``leaf_times``/``leaf_payloads``)."""
    return {"evq_tree": build_tree(jnp.full((queue_cap,), INF))}


# --------------------------------------------------------------------------
# Views (tests, debugging).
# --------------------------------------------------------------------------

def _leaf_base(tree) -> int:
    """Static leaf offset Qp from the array length 2*Qp + S (S < Qp)."""
    return 1 << int(math.floor(math.log2(tree.shape[0] // 2)))


def leaf_times(st):
    """(Qp,) per-slot event times from the leaf rows — INF marks a free
    slot.  Authoritative in tree mode (there is no ``ev_time``)."""
    tree = st["evq_tree"]
    qp = _leaf_base(tree)
    return tree[qp:2 * qp, 0]


def leaf_payloads(st):
    """(Qp, 4) per-slot [ev_type, a0, a1, a2] from the leaf rows."""
    tree = st["evq_tree"]
    qp = _leaf_base(tree)
    return tree[qp:2 * qp, 2:]


def freecnt(st):
    """(S,) i32 per-segment free counts from the counter rows."""
    tree = st["evq_tree"]
    qp = _leaf_base(tree)
    return tree[2 * qp:, 0].astype(jnp.int32)


# --------------------------------------------------------------------------
# Queue operations on the simulator state dict.
# --------------------------------------------------------------------------

def peek_time(st):
    """Earliest pending event time — the root, O(1).  The tree-mode
    while-loop condition is ``peek_time(st) < INF``."""
    return st["evq_tree"][1, 0]


def pop(st, depth: int):
    """Pop the earliest event: the root row IS the event — no argmin, no
    payload gathers.  Clear the leaf and repair its root path with one
    sibling gather, an unrolled running-winner register chain, and one
    path scatter (single-buffer: see module docstring).  Returns
    ``(st, t, slot, typ, a)`` with ``typ`` i32 and ``a`` (3,) i32 —
    exactly the values linear mode reads from ``ev_type``/``ev_a``."""
    qp = 1 << depth
    tree = st["evq_tree"]
    root = tree[1]
    t = root[0]
    slot = root[1].astype(jnp.int32)
    typ = root[2].astype(jnp.int32)
    a = root[3:].astype(jnp.int32)
    leaf = slot + qp
    path = leaf >> jnp.arange(depth + 1)             # leaf .. root
    sib = tree[path[:-1] ^ 1]                        # (depth, 6) one gather
    is_left = path[:-1] % 2 == 0                     # path node a left child?
    seg = slot // ALLOC_SEG
    cnt = tree[2 * qp + seg, 0]                      # free counter row
    # running winner row from the cleared leaf upward: each ancestor is
    # the pairwise winner of the running row and the unchanged sibling
    # row, the tie going to whichever child is on the left
    run = jnp.concatenate([jnp.stack([INF, slot.astype(jnp.float32), 0.0]),
                           jnp.zeros((3,))])
    rows = [run]
    for lvl in range(depth):
        pick = jnp.where(is_left[lvl], run[0] <= sib[lvl, 0],
                         run[0] < sib[lvl, 0])
        run = jnp.where(pick, run, sib[lvl])
        rows.append(run)
    # one scatter writes the whole path plus the freed-slot counter row
    # (index 2Qp+seg is disjoint from the path, which lies in [1, 2Qp))
    idx = jnp.concatenate([path, jnp.reshape(2 * qp + seg, (1,))])
    cnt_row = jnp.concatenate([jnp.reshape(cnt + 1.0, (1,)),
                               jnp.zeros((ROW_W - 1,))])
    new = jnp.concatenate([jnp.stack(rows), cnt_row[None, :]])
    st = dict(st)
    st["evq_tree"] = tree.at[idx].set(new)
    return st, t, slot, typ, a


def bulk_push(st, mask, times, typ, a0, a1, a2, depth: int, queue_cap: int):
    """Tree-mode twin of ``sim._bulk_push``: insert the masked entries of
    an event batch with the identical slot-assignment rule (the j-th
    masked entry takes the j-th lowest free slot) and identical overflow
    accounting (excess masked entries drop), but with the queue-wide
    argsort replaced by the segment-counted allocation and a
    level-parallel repair of only the touched tree paths."""
    q = queue_cap
    qp = 1 << depth
    tree = st["evq_tree"]
    s = tree.shape[0] - 2 * qp                       # counter rows
    mask = jnp.asarray(mask, bool)
    times = jnp.asarray(times, jnp.float32)

    # -- slot allocation: j-th masked entry -> j-th lowest free slot -----
    rank = jnp.cumsum(mask.astype(jnp.int32)) - 1    # rank among masked
    cnt = mask.sum()
    csum = jnp.cumsum(tree[2 * qp:, 0].astype(jnp.int32))  # (S,) counters
    total_free = csum[-1]
    # first segment whose cumulative free count reaches rank+1
    seg = jnp.searchsorted(csum, rank + 1, side="left").astype(jnp.int32)
    segc = jnp.minimum(seg, s - 1)                   # clamped (overflow)
    r = rank - jnp.where(segc > 0, csum[segc - 1], 0)  # rank within segment
    # the (r+1)-th free slot inside the segment, from a window of leaf
    # times (INF = free)
    cols = segc[:, None] * ALLOC_SEG + jnp.arange(ALLOC_SEG)[None, :]
    window = tree[qp + jnp.minimum(cols, q - 1), 0]
    free_w = jnp.logical_and(window >= INF, cols < q)
    hit = jnp.logical_and(free_w,
                          jnp.cumsum(free_w, axis=1) == r[:, None] + 1)
    slot = segc * ALLOC_SEG + jnp.argmax(hit, axis=1).astype(jnp.int32)
    ok = jnp.logical_and(mask, rank < total_free)

    st = dict(st)
    st["dropped"] = st["dropped"] + jnp.maximum(cnt - total_free, 0)

    # -- leaf + counter writes (out-of-range lanes drop) -----------------
    leaf_rows = jnp.stack([times, slot.astype(jnp.float32),
                           jnp.full(mask.shape, typ, jnp.float32),
                           jnp.asarray(a0, jnp.float32),
                           jnp.asarray(a1, jnp.float32),
                           jnp.asarray(a2, jnp.float32)], -1)
    oob = tree.shape[0]
    tree = tree.at[jnp.where(ok, slot + qp, oob)].set(leaf_rows, mode="drop")
    # an ok entry with time >= INF takes its slot in the assignment order
    # (as in linear mode) but leaves the leaf free, so it must not
    # decrement the segment counter — counters always equal the number
    # of INF leaves per segment (tests/test_eventq.py)
    dec = jnp.where(jnp.logical_and(ok, times < INF), -1.0, 0.0)
    tree = tree.at[jnp.where(ok, 2 * qp + segc, oob), 0].add(dec, mode="drop")

    # -- touched-path repair, level-parallel ----------------------------
    # Per level, the n touched parents gather their two children's rows,
    # take the winner, and scatter back.  Entries sharing a parent
    # compute identical rows (the gathers see all lower-level writes),
    # so duplicate scatters are order-independent; untouched nodes are
    # never written.
    two = jnp.arange(2)[None, :]                     # (1, 2) child offsets
    for lvl in range(depth):
        parent = (slot + qp) >> (lvl + 1)
        kids = tree[2 * parent[:, None] + two]       # (n, 2, 6) one gather
        take_l = kids[:, 0, 0] <= kids[:, 1, 0]      # ties -> left child
        prow = jnp.where(take_l[:, None], kids[:, 0], kids[:, 1])
        tree = tree.at[jnp.where(ok, parent, oob)].set(prow, mode="drop")
    st["evq_tree"] = tree
    return st


def empty(queue_cap: int) -> dict:
    """A minimal standalone queue state (no simulator around it) — the
    harness tests/test_eventq.py drives push/pop against directly."""
    return {"dropped": jnp.zeros((), jnp.int32)} | queue_state(queue_cap)
