"""Named metric accessors over simulator final states — the single home
of the result-side helpers (ISSUE 5 satellite: these used to live twice,
once unbatched in ``core/sim.py`` and once batched in ``core/sweep.py``).

Every function here is shape-polymorphic: it accepts a final-state dict
whose leaves carry any number of leading batch axes — ``()`` for a
single ``sim.run``, ``(B, S)`` for a ``sweep``/``ExperimentSpec`` grid —
and reduces only over the trailing per-application axis.  ``sim`` and
``sweep`` re-export these names unchanged, so
``repro.core.sim.speedup is repro.core.sweep.speedup is
repro.core.metrics.speedup`` (tests/test_experiment.py).

All computation is host-side numpy on materialized arrays: metrics are
read once per experiment, after the traced hot loop has finished.
"""
from __future__ import annotations

import numpy as np

__all__ = ["response_times", "mean_response", "speedup", "beacons",
           "beacons_rx", "mgmt_msgs", "mgmt_latency", "mgmt_proc"]

_DONE_SENTINEL = 1e17          # app_done/app_arrive hold INF=1e18 when unset


def response_times(state):
    """Masked response times: (tr (..., A) with NaN where incomplete,
    ok (..., A) completion mask)."""
    done = np.asarray(state["app_done"])
    arr = np.asarray(state["app_arrive"])
    ok = (done < _DONE_SENTINEL) & (arr < _DONE_SENTINEL)
    return np.where(ok, done - arr, np.nan), ok


def _masked_mean(x):
    """nanmean over the last axis without the all-NaN RuntimeWarning
    (empty lane -> nan)."""
    cnt = np.sum(~np.isnan(x), axis=-1)
    tot = np.nansum(x, axis=-1)
    return np.where(cnt > 0, tot / np.maximum(cnt, 1), np.nan)


def mean_response(state):
    """Mean response time over completed apps: (...,)."""
    tr, _ = response_times(state)
    return _masked_mean(tr)


def speedup(state, lengths):
    """Mean per-app speedup t_seq / t_par over completed apps: (...,).

    ``lengths`` is the child-length array of the workload, (A, n) for a
    single run or (S, A, n) for a sweep; missing leading axes broadcast
    against the state's batch axes (a (B, S, A) grid divides the same
    (S, A) sequential times across every knob config).
    """
    tr, ok = response_times(state)
    seq = np.asarray(lengths).sum(axis=-1)          # (..., A)
    while seq.ndim < tr.ndim:
        seq = seq[None]
    with np.errstate(invalid="ignore", divide="ignore"):
        s = np.where(ok, seq / tr, np.nan)
    return _masked_mean(s)


def beacons(state):
    """Transmitted status beacons: (...,) int64."""
    return np.asarray(state["beacons_tx"]).astype(np.int64)


def beacons_rx(state):
    """Per-receiver beacon deliveries (non-ideal topologies): (...,)."""
    return np.asarray(state["beacons_rx"]).astype(np.int64)


def mgmt_msgs(state):
    """Management messages transported (task-starts, join-exits and
    forwards, beacon deliveries): (...,) int64."""
    return np.asarray(state["mgmt_msgs"]).astype(np.int64)


def mgmt_latency(state):
    """Total management-message latency in ticks — the sum of
    (delivery - ready) over every transported message, i.e. the
    communication overhead of the management plane: (...,) float64."""
    return np.asarray(state["mgmt_latency"]).astype(np.float64)


def mgmt_proc(state):
    """Total manager-side queueing + service latency (fork expansion,
    stage-2 decision batches, barrier decrements) — the computation
    overhead of the management plane: (...,) float64."""
    return np.asarray(state["mgmt_proc"]).astype(np.float64)
