"""Unified declarative experiment API over the TLM design space.

The paper's contribution is a *design-space analysis* — centralized vs
clustered vs distributed, swept over cluster count, beacon thresholds
and fabric — but the sweep surface grew one ad-hoc entry point per axis
as the axes landed (``sweep_policies``, ``sweep_topologies``, the
``queue_impl`` kwarg, hand-rolled per-benchmark loops over k).  This
module replaces all of that with one declarative object
(DESIGN.md §12):

    spec = ExperimentSpec(
        base=SimParams(m=256, n_childs=100, max_apps=512, queue_cap=2048),
        shapes=(1, 8, 16, 32, 256),              # static: cluster counts
        policies=(("min_search", "threshold"),), # static: SimPolicy axis
        topologies=("ideal", "hier_tree"),       # static: Topology axis
        knobs={"dn_th": (1, 2, 4, 8, 16, 32)},   # traced: knob grid
        workloads=(WorkloadSpec("interference", seeds=(1, 2)),),
        sim_len=4e6)
    frame = spec.run()                           # ResultFrame
    frame.mean_response()                        # (N,) named accessors
    frame.col("k"), frame.col("dn_th")           # aligned coordinates

The **planner** (``spec.plan()``) partitions the point set into
*static-combo groups* — one per distinct ``(SimShape incl. queue_impl,
SimPolicy, Topology)`` — and each group compiles exactly one XLA
program (guarded by ``sweep.cache_size()`` deltas;
tests/test_experiment.py).  Everything inside a group (knob configs,
seeds, workload scenarios) rides the traced/vmap axes for free.

**Dispatch** executes each group with one of three strategies, all
bitwise identical (they run the very same traced computation):

  seq    warm replays of the single-config program, one compile per
         group — the CPU path (per-lane wall-clock recorded).
  vmap   one batched XLA program per group — the accelerator path.
  pmap   groups round-robined over devices via committed inputs, the
         whole frontier dispatched asynchronously and gathered once —
         the multi-device path (closes the ROADMAP "policy/topology
         axes on accelerator sweeps" item).  Falls back to seq/vmap
         when ``jax.device_count() == 1``.

The **faults axis** (DESIGN.md §13) rides alongside the workload axis:
``faults=(None, FaultSpec.poisson_links(seed=0), ...)`` crosses every
static combo with each fault scenario.  Fault schedules are *traced*
pytrees — within a spec they are padded to one common length per
cluster count, so a whole grid of fault seeds/intensities adds at most
one extra compilation per group (the fault-aware program; a bare
``None`` entry keeps the legacy no-fault program).  Each scenario
becomes a ``fault`` coordinate column plus ``msgs_lost`` / ``reroutes``
/ ``downtime`` metric columns (zero-filled for no-fault groups).

The returned :class:`ResultFrame` is columnar — every coordinate
(static axis value, knob value, workload lane, fault scenario) and
every metric is a flat aligned column over all points — and serializes
directly to the benchmarks' results-JSON schema v5 with the spec
embedded as provenance (``frame.to_payload()``; benchmarks/README.md).

Bitwise contract with the legacy entry points: a group executes through
the very same jitted programs ``sweep`` uses (``sim._run`` in seq mode,
``sweep._sweep`` in vmap/pmap mode) with identically-constructed
inputs, so every frozen golden (the PR-2 grid, the fig3b spot sha, the
tree==linear claims) reproduces bitwise through ``ExperimentSpec.run()``
(tests/test_experiment.py), and ``sweep_policies``/``sweep_topologies``
survive as thin deprecated shims over this module.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import faults as FLT
from repro.core import metrics as M
from repro.core import workloads as W
from repro.core.eventq import QUEUE_IMPLS
from repro.core.policies import SimPolicy
from repro.core.sim import SimKnobs, SimParams, SimShape, _run
from repro.core.transport import Topology

__all__ = ["WorkloadSpec", "ExperimentSpec", "ExperimentPlan", "StaticCombo",
           "ResultFrame", "spec_from_dict", "SPEC_VERSION"]

SPEC_VERSION = 2
MODES = ("auto", "seq", "vmap", "pmap")
WORKLOAD_KINDS = ("interference", "bursty", "hotspot", "independent", "raw")

KNOB_FIELDS = SimKnobs._fields          # (c_b, c_s, c_join, dn_th, T_b, c_hop)


# --------------------------------------------------------------------------
# Workload axis
# --------------------------------------------------------------------------

@dataclass(frozen=True, eq=False)
class WorkloadSpec:
    """One traced workload/scenario axis entry, declaratively.

    A spec is *regenerated per shape* (arrival GMNs depend on k, array
    sizes on max_apps/n_childs), which is what the benchmarks always did
    by hand; the generator params are recorded so the spec serializes as
    provenance.  ``kind="raw"`` wraps pre-built ``(arrivals (S, A),
    gmns (S, A), lengths (S, A, n))`` arrays for the legacy shims — raw
    arrays are shape-locked and serialize as shapes + sha256 only.
    """
    kind: str = "interference"
    seeds: tuple = (0,)
    params: tuple = ()                  # sorted (name, value) pairs
    arrays: tuple | None = None         # kind="raw" only

    def __post_init__(self):
        if self.kind not in WORKLOAD_KINDS:
            raise ValueError(f"unknown workload kind {self.kind!r}; "
                             f"choose from {WORKLOAD_KINDS}")
        object.__setattr__(self, "seeds", tuple(int(s) for s in self.seeds))
        params = self.params
        if isinstance(params, dict):
            params = tuple(sorted(params.items()))
        object.__setattr__(self, "params", tuple(
            (str(k), tuple(v) if isinstance(v, (list, tuple)) else v)
            for k, v in params))

    @classmethod
    def make(cls, kind: str = "interference", seeds=(0,), **params):
        return cls(kind=kind, seeds=seeds, params=tuple(sorted(params.items())))

    @classmethod
    def raw(cls, workload) -> "WorkloadSpec":
        arr, gmns, lens = (np.asarray(a) for a in workload)
        if arr.ndim != 2 or lens.ndim != 3:
            raise ValueError("raw workload needs a leading lane axis (S,): "
                             "arrivals (S, A), gmns (S, A), lengths (S, A, n)")
        return cls(kind="raw", seeds=(), arrays=(arr, gmns, lens))

    @property
    def param_dict(self) -> dict:
        return dict(self.params)

    def lane_count(self) -> int:
        """Number of S lanes this spec expands to (known without building)."""
        if self.kind == "raw":
            return int(self.arrays[0].shape[0])
        pps = self.param_dict.get("pair_periods")
        if self.kind == "interference" and pps is not None:
            return len(pps) * len(self.seeds)
        return len(self.seeds)

    def build(self, shape: SimShape, sim_len: float):
        """Materialize ``(lanes, (arrivals, gmns, lengths))`` for one
        static shape.  ``lanes`` is per-S metadata (seed, pair_period)
        that becomes ResultFrame coordinate columns."""
        prm = self.param_dict
        if self.kind == "raw":
            lanes = [{"workload": "raw", "seed": None, "pair_period": None}
                     for _ in range(self.arrays[0].shape[0])]
            return lanes, self.arrays
        if self.kind == "interference":
            pps = prm.pop("pair_periods", None)
            if pps is not None:
                wl = W.interference_grid(shape, pair_periods=pps,
                                         seeds=self.seeds, sim_len=sim_len,
                                         **prm)
                lanes = [{"workload": self.kind, "seed": s,
                          "pair_period": float(pp)}
                         for pp in pps for s in self.seeds]
            else:
                wl = W.interference_batch(shape, seeds=self.seeds,
                                          sim_len=sim_len, **prm)
                pp = prm.get("pair_period")
                if pp is None:
                    pp = W.DEFAULT_PAIR_PERIOD
                lanes = [{"workload": self.kind, "seed": s,
                          "pair_period": float(pp)} for s in self.seeds]
            return lanes, wl
        if self.kind == "bursty":
            wl = W.bursty_batch(shape, seeds=self.seeds, sim_len=sim_len,
                                **prm)
        elif self.kind == "hotspot":
            wl = W.hotspot_batch(shape, seeds=self.seeds, sim_len=sim_len,
                                 **prm)
        else:                                           # independent
            wl = W.independent_batch(shape, seeds=self.seeds, **prm)
        lanes = [{"workload": self.kind, "seed": s, "pair_period": None}
                 for s in self.seeds]
        return lanes, wl

    def to_dict(self) -> dict:
        d = {"kind": self.kind, "seeds": list(self.seeds),
             "params": {k: (list(v) if isinstance(v, tuple) else v)
                        for k, v in self.params}}
        if self.arrays is not None:
            h = hashlib.sha256()
            for a in self.arrays:
                h.update(np.ascontiguousarray(a).tobytes())
            d["raw"] = {"shapes": [list(a.shape) for a in self.arrays],
                        "sha256": h.hexdigest()}
        return d


# --------------------------------------------------------------------------
# Planner
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class StaticCombo:
    """One static-combo group: exactly one XLA program compiles per
    distinct value (``queue_impl`` is folded into ``shape``)."""
    shape: SimShape
    policy: SimPolicy
    topology: Topology

    def coords(self) -> dict:
        return {"m": self.shape.m, "k": self.shape.k,
                "n_childs": self.shape.n_childs,
                "queue_cap": self.shape.queue_cap,
                "max_apps": self.shape.max_apps,
                "queue_impl": self.shape.queue_impl,
                "mapping": self.policy.mapping,
                "beacon": self.policy.beacon,
                "topology": self.topology.kind}


@dataclass(frozen=True)
class ExperimentPlan:
    """The compile-aware partition of a spec's point set.

    ``combos`` is the minimal static-combo grouping: the Cartesian
    product of the spec's static axes, deduplicated order-preservingly —
    no two groups share a ``(shape, policy, topology)`` value, so the
    number of XLA compilations is exactly :meth:`expected_programs`
    on a fresh cache (DESIGN.md §12).
    """
    spec: "ExperimentSpec"
    combos: tuple

    @property
    def n_groups(self) -> int:
        return len(self.combos)

    def resolve_mode(self, mode: str | None = None) -> str:
        """Dispatch matrix (DESIGN.md §12): auto picks seq on CPU and
        vmap on accelerators; pmap needs >1 device and falls back to the
        auto choice cleanly on single-device backends."""
        mode = mode or self.spec.mode
        if mode not in MODES:
            raise ValueError(f"unknown mode {mode!r}; choose from {MODES}")
        if mode == "pmap" and jax.device_count() <= 1:
            mode = "auto"
        if mode == "auto":
            mode = "seq" if jax.default_backend() == "cpu" else "vmap"
        return mode

    def expected_programs(self, mode: str | None = None) -> int:
        """XLA programs a fresh cache compiles executing this plan:
        one per group in seq mode; in vmap/pmap mode the batched program
        is additionally specialized on the lane count S, so scenarios
        with distinct lane counts each compile once per group.  The
        faults axis contributes at most a factor of two per group — one
        no-fault program (``None`` entries) and one fault-aware program
        shared by every FaultSpec (schedules are padded to one common
        length per k, so fault-schedule grids never recompile)."""
        mode = self.resolve_mode(mode)
        fault_programs = len({f is None for f in self.spec.faults})
        if mode == "seq":
            return self.n_groups * fault_programs
        lane_shapes = {w.lane_count() for w in self.spec.workloads}
        return self.n_groups * len(lane_shapes) * fault_programs


# --------------------------------------------------------------------------
# The spec
# --------------------------------------------------------------------------

@dataclass(frozen=True, eq=False)
class ExperimentSpec:
    """One declarative object for every design-space axis.

    Static axes (each value = its own XLA program; the planner groups
    by them):

      shapes       SimShape values; also accepts SimParams (its .shape)
                   or a bare int k (``base``'s shape with k replaced).
                   None -> (base.shape,).
      policies     SimPolicy values or (mapping, beacon) tuples.
                   None -> (base.policy,).
      topologies   Topology values or kind strings.  None -> (base.topo,).
      queue_impls  event-queue structures crossed with ``shapes``
                   (folded into each group's SimShape).  None keeps each
                   shape's own ``queue_impl``.

    Traced axes (ride inside each group's compiled program):

      knobs        SimKnobs with a leading (B,) axis, or a dict of knob
                   axes expanded Cartesian-product style
                   (``{"dn_th": (1, 2, 4), "c_s": (8.0,)}``).
                   None -> one config from ``base``.
      workloads    WorkloadSpec tuple — the scenario/seed axis.
      faults       fault-scenario axis (DESIGN.md §13): a tuple of
                   ``None`` (legacy no-fault program) and/or
                   :class:`repro.core.faults.FaultSpec` values, crossed
                   with every group.  Schedules are traced and padded to
                   a common length per k, so the whole axis costs at
                   most one extra program per group.  Default (None,).

    ``run()`` plans, dispatches and returns a :class:`ResultFrame`.
    """
    base: SimParams = SimParams()
    shapes: tuple | None = None
    policies: tuple | None = None
    topologies: tuple | None = None
    queue_impls: tuple | None = None
    knobs: object = None
    workloads: tuple = (WorkloadSpec(),)
    faults: tuple = (None,)
    sim_len: float = 1e7
    mode: str = "auto"

    def __post_init__(self):
        base = self.base
        set_ = lambda k, v: object.__setattr__(self, k, v)

        shapes = self.shapes if self.shapes is not None else (base.shape,)
        set_("shapes", tuple(
            dataclasses.replace(base.shape, k=int(s))
            if isinstance(s, (int, np.integer))
            else s.shape if isinstance(s, SimParams) else s
            for s in _as_tuple(shapes)))

        pols = self.policies if self.policies is not None else (base.policy,)
        set_("policies", tuple(
            p if isinstance(p, SimPolicy) else SimPolicy(*p)
            for p in _as_tuple(pols)))

        topos = self.topologies if self.topologies is not None \
            else (base.topo,)
        set_("topologies", tuple(
            Topology(t) if isinstance(t, str) else t
            for t in _as_tuple(topos)))

        if self.queue_impls is not None:
            qis = tuple(_as_tuple(self.queue_impls))
            for qi in qis:
                if qi not in QUEUE_IMPLS:
                    raise ValueError(f"unknown queue_impl {qi!r}; "
                                     f"choose from {QUEUE_IMPLS}")
            set_("queue_impls", qis)

        knobs = self.knobs
        if knobs is None:
            knobs = {}
        if isinstance(knobs, dict):
            defaults = {f: getattr(base, f) for f in KNOB_FIELDS}
            unknown = set(knobs) - set(KNOB_FIELDS)
            if unknown:
                raise ValueError(f"unknown knob axes {sorted(unknown)}; "
                                 f"choose from {KNOB_FIELDS}")
            from repro.core import sweep as SW
            knobs = SW.knob_product(**{
                f: np.atleast_1d(knobs.get(f, defaults[f]))
                for f in KNOB_FIELDS})
        if knobs.dn_th.ndim != 1:
            raise ValueError("knobs need a leading batch axis (B,); "
                             "pass a dict of axes or knob_batch/knob_product")
        set_("knobs", knobs)

        wls = self.workloads
        if isinstance(wls, WorkloadSpec):
            wls = (wls,)
        set_("workloads", tuple(wls))
        if not self.workloads:
            raise ValueError("need at least one WorkloadSpec")

        flts = self.faults
        if flts is None or isinstance(flts, FLT.FaultSpec):
            flts = (flts,)
        flts = tuple(flts)
        for f in flts:
            if f is not None and not isinstance(f, FLT.FaultSpec):
                raise TypeError(f"faults entries must be None or FaultSpec, "
                                f"got {type(f).__name__}")
        if not flts:
            raise ValueError("faults needs at least one entry "
                             "(use (None,) for no faults)")
        set_("faults", flts)
        set_("sim_len", float(self.sim_len))
        if self.mode not in MODES:
            raise ValueError(f"unknown mode {self.mode!r}; "
                             f"choose from {MODES}")

    # -- planner ----------------------------------------------------------

    def plan(self) -> ExperimentPlan:
        combos = []
        for shape in self.shapes:
            qis = self.queue_impls or (shape.queue_impl,)
            for qi in qis:
                sh = shape if shape.queue_impl == qi \
                    else dataclasses.replace(shape, queue_impl=qi)
                for pol in self.policies:
                    for topo in self.topologies:
                        combos.append(StaticCombo(sh, pol, topo))
        return ExperimentPlan(self, tuple(dict.fromkeys(combos)))

    # -- execution --------------------------------------------------------

    def run(self, mode: str | None = None) -> "ResultFrame":
        from repro.core import sweep as SW
        plan = self.plan()
        requested = mode or self.mode
        resolved = plan.resolve_mode(requested)
        compiles0 = SW.cache_size()
        sl = jnp.float32(self.sim_len)
        wl_cache = {}
        f_cache = {}

        def built(combo, wi):
            key = (wi, combo.shape.m, combo.shape.k, combo.shape.max_apps,
                   combo.shape.n_childs)
            if key not in wl_cache:
                lanes, wl = self.workloads[wi].build(combo.shape,
                                                     self.sim_len)
                wl_cache[key] = (lanes, (
                    jnp.asarray(wl[0], jnp.float32),
                    jnp.asarray(wl[1], jnp.int32),
                    jnp.asarray(wl[2], jnp.float32)))
            return wl_cache[key]

        def scheds(k):
            # one build per (fault entry, k), padded to the axis-wide
            # common length so every FaultSpec shares one program per
            # group (expected_programs' no-recompile contract)
            if k not in f_cache:
                built_ = [None if f is None else f.build(k, self.sim_len)
                          for f in self.faults]
                cap = max((s.capacity for s in built_ if s is not None),
                          default=0)
                f_cache[k] = [None if s is None else FLT.pad_to(s, cap)
                              for s in built_]
            return f_cache[k]

        t0 = time.time()
        groups = []
        if resolved == "pmap":
            devs = jax.devices()
            pending = []
            for gi, combo in enumerate(plan.combos):
                dev = devs[gi % len(devs)]
                for wi in range(len(self.workloads)):
                    lanes, (arr, gmns, lens) = built(combo, wi)
                    for fi, f in enumerate(self.faults):
                        kn, ar, gm, ln, sl_d, fs = jax.device_put(
                            (self.knobs, arr, gmns, lens, sl,
                             scheds(combo.shape.k)[fi]), dev)
                        out = SW._sweep(combo.shape, kn, ar, gm, ln, sl_d,
                                        combo.policy, combo.topology, fs)
                        pending.append((combo, wi, f, lanes, lens, out))
            for combo, wi, f, lanes, lens, out in pending:
                st = jax.tree.map(np.asarray, jax.block_until_ready(out))
                groups.append(_GroupResult(combo, wi, lanes, st,
                                           np.asarray(lens), np.nan, None,
                                           f))
        else:
            for combo in plan.combos:
                for wi in range(len(self.workloads)):
                    lanes, (arr, gmns, lens) = built(combo, wi)
                    for fi, f in enumerate(self.faults):
                        fs = scheds(combo.shape.k)[fi]
                        tg = time.time()
                        if resolved == "vmap":
                            st = SW._sweep(combo.shape, self.knobs, arr,
                                           gmns, lens, sl, combo.policy,
                                           combo.topology, fs)
                            st = jax.tree.map(np.asarray,
                                              jax.block_until_ready(st))
                            lane_walls = None
                        else:
                            st, lane_walls = _exec_seq(
                                combo, self.knobs, arr, gmns, lens, sl, fs)
                        groups.append(_GroupResult(combo, wi, lanes, st,
                                                   np.asarray(lens),
                                                   time.time() - tg,
                                                   lane_walls, f))
        wall = time.time() - t0
        return ResultFrame(self, plan, requested, resolved, groups, wall,
                           SW.cache_size() - compiles0)

    # -- provenance -------------------------------------------------------

    def to_dict(self) -> dict:
        return {
            "version": SPEC_VERSION,
            "base": dataclasses.asdict(self.base),
            "shapes": [dataclasses.asdict(s) for s in self.shapes],
            "policies": [{"mapping": p.mapping, "beacon": p.beacon}
                         for p in self.policies],
            "topologies": [t.kind for t in self.topologies],
            "queue_impls": list(self.queue_impls) if self.queue_impls
            else None,
            "knobs": {f: np.asarray(getattr(self.knobs, f)).tolist()
                      for f in KNOB_FIELDS},
            "workloads": [w.to_dict() for w in self.workloads],
            "faults": [None if f is None else f.to_dict()
                       for f in self.faults],
            "sim_len": float(self.sim_len),
            "mode": self.mode,
        }


def _as_tuple(v):
    return (v,) if not isinstance(v, (tuple, list)) else tuple(v)


_SPEC_FIELDS = ("version", "base", "shapes", "policies", "topologies",
                "queue_impls", "knobs", "workloads", "faults", "sim_len",
                "mode")


def spec_from_dict(d: dict) -> ExperimentSpec:
    """Reconstruct an ExperimentSpec from its ``to_dict()`` payload (the
    provenance round-trip; raw workloads carry only shapes + sha256 and
    cannot be reconstructed).

    Strict: a payload field this reader does not know is an error, not
    a silent drop — a spec written by a newer schema (say a v5 payload
    with an axis this version cannot replay) must fail loudly instead of
    reconstructing a spec that silently runs *different* experiments
    than the payload records (tests/test_experiment.py)."""
    from repro.core import sweep as SW
    unknown = set(d) - set(_SPEC_FIELDS)
    if unknown:
        raise ValueError(
            f"unknown ExperimentSpec fields {sorted(unknown)}; this reader "
            f"(SPEC_VERSION={SPEC_VERSION}) supports {sorted(_SPEC_FIELDS)} "
            "— the payload was likely written by a newer schema and cannot "
            "be replayed faithfully")
    version = int(d.get("version", 1))
    if version > SPEC_VERSION:
        raise ValueError(f"payload has spec version {version}, this reader "
                         f"supports <= {SPEC_VERSION}")
    for w in d["workloads"]:
        if w["kind"] == "raw":
            raise ValueError("raw workloads serialize as provenance only "
                             "and cannot be reconstructed")
    return ExperimentSpec(
        base=SimParams(**d["base"]),
        shapes=tuple(SimShape(**s) for s in d["shapes"]),
        policies=tuple(SimPolicy(**p) for p in d["policies"]),
        topologies=tuple(d["topologies"]),
        queue_impls=tuple(d["queue_impls"]) if d.get("queue_impls")
        else None,
        knobs=SW.knob_batch(**{f: tuple(v) if len(v) > 1 else v[0]
                               for f, v in d["knobs"].items()}),
        workloads=tuple(
            WorkloadSpec(kind=w["kind"], seeds=tuple(w["seeds"]),
                         params=tuple(sorted(
                             (k, tuple(v) if isinstance(v, list) else v)
                             for k, v in w["params"].items())))
            for w in d["workloads"]),
        faults=tuple(None if f is None else FLT.FaultSpec.from_dict(f)
                     for f in d.get("faults", [None])),
        sim_len=d["sim_len"],
        mode=d["mode"])


def _exec_seq(combo: StaticCombo, knobs: SimKnobs, arr, gmns, lens, sl,
              faults=None):
    """Warm replays of the single-config program — the identical
    ``sim._run`` calls and (B, S)-stacking ``sweep(mode="seq")`` performs,
    with per-lane wall-clock recorded (lane 0 of a fresh group carries
    the XLA compile)."""
    b, s = knobs.dn_th.shape[0], arr.shape[0]
    outs, lane_walls = [], []
    for i in range(b):
        for j in range(s):
            tl = time.time()
            out = jax.block_until_ready(
                _run(combo.shape, SimKnobs(*(leaf[i] for leaf in knobs)),
                     arr[j], gmns[j], lens[j], sl, combo.policy,
                     combo.topology, faults))
            lane_walls.append(time.time() - tl)
            outs.append(out)
    st = jax.tree.map(
        lambda *leaves: np.stack(leaves).reshape((b, s) + leaves[0].shape),
        *[jax.tree.map(np.asarray, o) for o in outs])
    return st, lane_walls


# --------------------------------------------------------------------------
# Columnar results
# --------------------------------------------------------------------------

def _opt_leaf(st: dict, name: str, dtype) -> np.ndarray:
    """A (B, S) scalar state leaf, or zeros of the right shape when the
    group's program did not record it (no-fault groups lack the fault
    counters)."""
    v = st.get(name)
    if v is None:
        v = np.zeros(np.asarray(st["dropped"]).shape)
    return np.asarray(v).astype(dtype)

@dataclass
class _GroupResult:
    combo: StaticCombo
    workload_index: int
    lanes: list                         # per-S metadata dicts
    state: dict                         # np leaves, (B, S, ...)
    lengths: np.ndarray                 # (S, A, n)
    wall_s: float
    lane_wall_s: list | None            # B*S entries (seq mode) or None
    fault: object = None                # FaultSpec or None (no-fault)

    @property
    def fault_label(self) -> str:
        return self.fault.label if self.fault is not None else "none"


class ResultFrame:
    """Columnar result set: one row per (group x knob-config x lane)
    point, flat aligned columns for every coordinate and metric.

    Point order is group-major (plan order), then workload-spec order,
    then fault-scenario order, then knob-config-major / lane-minor —
    i.e. each group's ``(B, S)`` state leaves flattened C-style,
    matching ``sweep``'s axis contract.
    """

    _METRICS = {
        "mean_response": M.mean_response,
        "beacons_tx": M.beacons,
        "beacons_rx": M.beacons_rx,
        "mgmt_msgs": M.mgmt_msgs,
        "mgmt_latency": M.mgmt_latency,
        "mgmt_proc": M.mgmt_proc,
        "dropped": lambda st: np.asarray(st["dropped"]).astype(np.int64),
        "events": lambda st:
            np.asarray(st["events_processed"]).astype(np.int64),
        "bcn_skew_sum": lambda st: np.asarray(st["bcn_skew_sum"],
                                              np.float64),
        "bcn_skew_max": lambda st: np.asarray(st["bcn_skew_max"],
                                              np.float64),
        # availability counters (DESIGN.md §13) — zero-filled when the
        # group ran the legacy no-fault program and the leaves are absent
        "msgs_lost": lambda st: _opt_leaf(st, "msgs_lost", np.int64),
        "reroutes": lambda st: _opt_leaf(st, "reroutes", np.int64),
        "downtime": lambda st: _opt_leaf(st, "downtime", np.float64),
    }
    COORDS = ("m", "k", "n_childs", "queue_cap", "max_apps", "queue_impl",
              "mapping", "beacon", "topology", "fault")
    LANE_COORDS = ("workload", "seed", "pair_period")

    def __init__(self, spec, plan, mode_requested, mode, groups, wall_s,
                 compiles):
        self.spec = spec
        self.plan = plan
        self.mode_requested = mode_requested
        self.mode = mode
        self.groups = groups
        self.wall_s = wall_s
        self.compiles = compiles
        self.expected_programs = plan.expected_programs(mode)
        self._cols = None

    def __len__(self):
        b = self.spec.knobs.dn_th.shape[0]
        return sum(b * len(g.lanes) for g in self.groups)

    # -- columns ----------------------------------------------------------

    def _columns(self) -> dict:
        if self._cols is not None:
            return self._cols
        cols = {name: [] for name in
                self.COORDS + self.LANE_COORDS + KNOB_FIELDS
                + tuple(self._METRICS) + ("speedup", "lane_wall_s")}
        b = self.spec.knobs.dn_th.shape[0]
        knob_rows = {f: np.asarray(getattr(self.spec.knobs, f))
                     for f in KNOB_FIELDS}
        for g in self.groups:
            s = len(g.lanes)
            n = b * s
            met = {name: np.asarray(fn(g.state)).reshape(n)
                   for name, fn in self._METRICS.items()}
            met["speedup"] = np.asarray(
                M.speedup(g.state, g.lengths)).reshape(n)
            met["lane_wall_s"] = (np.asarray(g.lane_wall_s)
                                  if g.lane_wall_s is not None
                                  else np.full((n,), np.nan))
            coords = dict(g.combo.coords(), fault=g.fault_label)
            for i in range(b):
                for j in range(s):
                    for c in self.COORDS:
                        cols[c].append(coords[c])
                    lane = g.lanes[j]
                    for c in self.LANE_COORDS:
                        cols[c].append(lane.get(c))
                    for f in KNOB_FIELDS:
                        cols[f].append(knob_rows[f][i].item())
            for name in tuple(self._METRICS) + ("speedup", "lane_wall_s"):
                cols[name].extend(met[name].tolist())
        self._cols = {k: np.asarray(v) for k, v in cols.items()}
        return self._cols

    def col(self, name: str) -> np.ndarray:
        """Flat (N,) column aligned across coordinates and metrics."""
        cols = self._columns()
        if name not in cols:
            raise KeyError(f"unknown column {name!r}; available: "
                           f"{sorted(cols)}")
        return cols[name]

    def mask(self, **sel) -> np.ndarray:
        """Boolean point mask, e.g. ``frame.mask(k=16, topology="ideal")``.

        Knob coordinates are stored at the simulator's float32 precision,
        so float selectors on knob columns are rounded through float32
        before comparing — ``frame.mask(c_s=0.1)`` matches the lane that
        actually ran with ``float32(0.1)``."""
        m = np.ones((len(self),), bool)
        for k, v in sel.items():
            if k in KNOB_FIELDS and isinstance(v, float):
                v = np.float32(v).item()
            m &= self.col(k) == v
        return m

    # -- named metric accessors (generated below the class: one per
    # metric column — mean_response, speedup, beacons_tx, beacons_rx,
    # mgmt_msgs, mgmt_latency, mgmt_proc, dropped, events, bcn_skew_*) --

    def metric(self, name: str, **sel) -> np.ndarray:
        """The (N,) metric column ``name``, optionally filtered by
        coordinate selectors: ``frame.metric("speedup", k=16)``."""
        col = self.col(name)
        return col[self.mask(**sel)] if sel else col

    # -- raw state access (bitwise golden gates) --------------------------

    def state(self, workload_index: int = 0, **sel) -> dict:
        """The raw (B, S, ...) final-state dict of exactly one group —
        select by static coordinates (``k=16, topology="hier_tree",
        mapping="round_robin", queue_impl="tree", fault="none"``...;
        ``fault`` matches the scenario label).  This is the
        bitwise surface: leaves are the very arrays the group's jitted
        program returned."""
        hits = [g for g in self.groups
                if g.workload_index == workload_index
                and all(dict(g.combo.coords(),
                             fault=g.fault_label).get(k) == v
                        for k, v in sel.items())]
        if len(hits) != 1:
            raise KeyError(f"state selector {sel} (workload_index="
                           f"{workload_index}) matched {len(hits)} groups, "
                           "need exactly 1")
        return hits[0].state

    # -- serialization (schema v4) ----------------------------------------

    def rows(self) -> list:
        """One JSON-ready dict per point (coordinates + knobs + metrics)."""
        cols = self._columns()
        out = []
        for i in range(len(self)):
            row = {}
            for k, v in cols.items():
                v = v[i]
                if isinstance(v, np.generic):
                    v = v.item()
                if isinstance(v, float) and np.isnan(v):
                    v = None
                row[k] = v
            out.append(row)
        return out

    def to_payload(self, **extra) -> dict:
        """The benchmarks' results-JSON schema v4 core: embedded spec
        provenance + planner/dispatch accounting + columnar rows."""
        return {
            "spec": self.spec.to_dict(),
            "experiment": {
                "mode_requested": self.mode_requested,
                "mode": self.mode,
                "n_groups": self.plan.n_groups,
                "n_points": len(self),
                "n_compiles": self.compiles,
                "expected_programs": self.expected_programs,
                "wall_s": self.wall_s,
                "devices": jax.device_count(),
            },
            "rows": self.rows(),
            **extra,
        }


def _metric_accessor(name):
    def acc(self, **sel):
        return self.metric(name, **sel)
    acc.__name__ = name
    acc.__qualname__ = f"ResultFrame.{name}"
    acc.__doc__ = (f"Aligned (N,) ``{name}`` column; keyword coordinate "
                   f"selectors filter points (``frame.{name}(k=16)``).")
    return acc


for _name in tuple(ResultFrame._METRICS) + ("speedup",):
    setattr(ResultFrame, _name, _metric_accessor(_name))
del _name
