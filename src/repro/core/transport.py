"""Interconnect transport model for the management plane (DESIGN.md §10).

The paper's claim is architectural: a *clustered* manager infrastructure
reduces the communication overhead of run-time management versus
centralized and fully-distributed configurations (Sec 5.4).  Until this
module existed the simulator delivered every beacon atomically at its
single bus-grant time (deviation §8.2), so the message-passing protocol
the paper analyzes was effectively free and skew-less.  ``Topology``
makes the fabric an explicit, static design-space axis:

  ``ideal``       the historical behavior, kept bitwise: one global bus
                  for inter-cluster messages, k local buses for
                  intra-cluster ones, beacons update every view
                  atomically at the global-bus grant.
  ``shared_bus``  a single serialized bus carries *all* management
                  messages (intra-cluster ones included), and a beacon
                  broadcast degenerates to k-1 back-to-back unicasts —
                  the contention-heavy flat-bus baseline.
  ``hier_tree``   the paper's physical fabric: global bus + k local
                  buses, each hop paying a serialized grant (``c_b``).
                  An inter-cluster message crosses the global bus and
                  then the *destination* cluster's local bus, so beacon
                  deliveries contend with local traffic per receiver.
  ``mesh2d``      GMNs on a ⌈√k⌉ x ⌈√k⌉ grid (a GMN mesh network):
                  injection serializes on the source's local port, then
                  delivery costs Manhattan-hops x ``c_hop`` — latency
                  scales with physical distance, no shared medium.

Like ``SimPolicy``, a ``Topology`` is hashable and static: each kind
compiles its own XLA program, and the untaken fabric models cost
nothing.  The numeric transport parameters — the bus service time
``c_b`` and the per-hop mesh latency ``c_hop`` — stay traced
``SimKnobs`` leaves, so knob/seed grids under any topology remain one
compilation per (shape, policy, topology).

Under the non-ideal kinds, a fired beacon becomes k-1 in-flight entries
in a (k, k) delivery matrix (``bcn_t``, rows = source, columns =
receiver, tracking the latest pending arrival per pair) and one
``BEACON_RX`` event per receiver; views then update at per-receiver
arrival times, so ``view_t``/``age`` in ``core/policies.py`` genuinely
differ across receivers.  Arrivals from one source to one receiver are
strictly increasing in send order (``c_b > 0`` serializes the source),
so deliveries apply FIFO per pair and conservation is exact:

    beacons_rx == (k - 1) * beacons_tx

with the matrix draining to empty by the end of every run
(tests/test_transport.py).  The wall-clock analog for the serving
engine (``serving/engine.FleetSim``) uses :func:`host_beacon_delays`,
stateless per-receiver delays in the same shapes.

Under fault injection (repro.core.faults, DESIGN.md §13) every message
class routes through the traced (k, k) ``link_up`` mask: beacons are
best-effort (a down link or dead receiver drops the delivery into
``msgs_lost``, generalizing conservation to ``beacons_rx + msgs_lost
== (k-1) * beacons_tx``), while task-start groups and join-exit
forwards are reliable and pay :func:`link_penalty` — a detour
(``2 * c_hop`` on mesh2d) or retransmit grant pair (``2 * c_b``
elsewhere) counted in ``reroutes``.  On an all-up mask every penalty is
exactly 0.0, so the fault-aware programs reproduce the frozen goldens
bitwise.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

TOPOLOGIES = ("ideal", "shared_bus", "hier_tree", "mesh2d")


@dataclass(frozen=True)
class Topology:
    """Static fabric selection: hashable, one XLA program per kind."""
    kind: str = "ideal"

    def __post_init__(self):
        if self.kind not in TOPOLOGIES:
            raise ValueError(f"unknown topology {self.kind!r}; "
                             f"choose from {TOPOLOGIES}")


DEFAULT_TOPOLOGY = Topology()


def topology_grid(kinds=TOPOLOGIES):
    """All topology kinds as Topology values (the static sweep axis)."""
    return [Topology(kind) for kind in kinds]


def grid_side(k: int) -> int:
    """Side of the smallest square GMN grid holding k nodes."""
    return max(1, math.isqrt(k - 1) + 1) if k > 1 else 1


def mesh_hops(k: int) -> np.ndarray:
    """(k, k) Manhattan hop counts between GMNs placed row-major on a
    ``grid_side(k)``-wide 2D grid.  Symmetric, zero diagonal; static —
    it becomes an XLA constant inside the compiled program."""
    s = grid_side(k)
    pos = np.arange(k)
    x, y = pos // s, pos % s
    return (np.abs(x[:, None] - x[None, :])
            + np.abs(y[:, None] - y[None, :])).astype(np.int32)


# ==========================================================================
# Traced fabric primitives (used by repro.core.sim's event handlers).
#
# All of them branch on ``topo.kind`` at trace time (the topology is
# static), take the bus-occupancy state explicitly and return it updated;
# every returned latency is (delivery - ready), the per-message
# communication overhead accumulated into ``mgmt_latency``.  The ideal
# branches reproduce the historical inline bus code operation-for-
# operation — that is the bitwise-golden contract of
# tests/test_sweep.py.
# ==========================================================================

def unicast(topo: Topology, src, dst, t_ready, is_remote, *, gbus, lbus,
            c_b, c_hop, hops):
    """One inter-GMN management message (stage-1 task-start group).

    Returns ``(t_arr, gbus, lbus, latency)``.  A self-targeted message
    (``is_remote`` false) is a local data-structure operation in every
    topology: it arrives at ``t_ready`` and touches no fabric.
    """
    if topo.kind in ("ideal", "shared_bus"):
        # one serialized grant on the single global/shared bus
        t_bus = jnp.maximum(t_ready, gbus) + c_b
        gbus = jnp.where(is_remote, t_bus, gbus)
        t_arr = jnp.where(is_remote, t_bus, t_ready)
    elif topo.kind == "hier_tree":
        # global-bus hop, then the destination cluster's local-bus hop
        t_g = jnp.maximum(t_ready, gbus) + c_b
        gbus = jnp.where(is_remote, t_g, gbus)
        t_in = jnp.maximum(t_g, lbus[dst]) + c_b
        lbus = jnp.where(is_remote, _set1(lbus, dst, t_in), lbus)
        t_arr = jnp.where(is_remote, t_in, t_ready)
    elif topo.kind == "mesh2d":
        # serialized injection at the source port, then hop latency
        t_inj = jnp.maximum(t_ready, lbus[src]) + c_b
        lbus = jnp.where(is_remote, _set1(lbus, src, t_inj), lbus)
        t_arr = jnp.where(is_remote,
                          t_inj + hops[src, dst].astype(jnp.float32) * c_hop,
                          t_ready)
    return t_arr, gbus, lbus, jnp.where(is_remote, t_arr - t_ready, 0.0)


def forward(topo: Topology, src, dst, t_ready, is_remote, *, gbus, lbus,
            c_b, c_hop, hops):
    """A remote join-exit forward from GMN ``src`` to the barrier GMN
    ``dst`` — same fabric path as :func:`unicast`, separate entry point
    so the accounting and DESIGN.md can name the message class."""
    return unicast(topo, src, dst, t_ready, is_remote, gbus=gbus, lbus=lbus,
                   c_b=c_b, c_hop=c_hop, hops=hops)


def link_penalty(topo: Topology, up, is_remote, *, c_b, c_hop):
    """Extra delivery latency a *reliable* management message (task-start
    group, join-exit forward) pays when its (src, dst) link is down
    (DESIGN.md §13).  Reliable messages are never lost — the fabric
    detours them:

      mesh2d     the XY route is blocked; the dimension-ordered detour
                 around the failed link costs two extra hops
                 (``2 * c_hop``).
      otherwise  the bus-based fabrics retransmit through the
                 supervisor path: one extra grant pair (``2 * c_b``).

    Returns the traced penalty (0.0 when the link is up, the message is
    local, or faults are disabled) — adding it to an arrival time is an
    exact no-op on an all-up mask, which is the bitwise no-fault
    contract the frozen goldens ride on.  ``up`` is the (src, dst) entry
    of the traced ``link_up`` mask."""
    base = 2.0 * (c_hop if topo.kind == "mesh2d" else c_b)
    hit = jnp.logical_and(is_remote, up == 0)
    return jnp.where(hit, base, 0.0)


def beacon_tx(topo: Topology, g, t, fire, *, gbus, lbus, c_b, c_hop, hops,
              k: int):
    """Transmit a status beacon from GMN ``g`` at tick ``t`` (masked by
    the traced ``fire``; bus state only advances where it fires).

    Returns ``(t_tx, t_arr, gbus, lbus)``: ``t_tx`` the transmission
    grant (feeds ``last_bcast_t``), ``t_arr`` (k,) per-receiver arrival
    times (entry ``g`` is meaningless — the caller masks it out).
    Only defined for the non-ideal kinds; ``ideal`` keeps the historical
    atomic-update path inside ``sim._maybe_beacon``.
    """
    if topo.kind == "shared_bus":
        # no hardware broadcast on the flat bus: k-1 back-to-back
        # unicasts in own-first order, one serialized grant (c_b) each
        t0 = jnp.maximum(t, gbus) + c_b
        j = jnp.mod(jnp.arange(k) - g, k)            # own-first rank, own = 0
        t_arr = t0 + (j - 1).astype(jnp.float32) * c_b
        t_last = t0 + jnp.float32(max(k - 2, 0)) * c_b
        gbus = jnp.where(fire, t_last, gbus)
        return t0, t_arr, gbus, lbus
    if topo.kind == "hier_tree":
        # one global-bus grant, then each receiver's local-bus grant
        t_g = jnp.maximum(t, gbus) + c_b
        gbus = jnp.where(fire, t_g, gbus)
        t_arr = jnp.maximum(t_g, lbus) + c_b
        rcv = jnp.arange(k) != g
        lbus = jnp.where(jnp.logical_and(fire, rcv), t_arr, lbus)
        return t_g, t_arr, gbus, lbus
    if topo.kind == "mesh2d":
        # one serialized injection, then per-receiver hop latency
        t_inj = jnp.maximum(t, lbus[g]) + c_b
        lbus = jnp.where(fire, _set1(lbus, g, t_inj), lbus)
        t_arr = t_inj + hops[g].astype(jnp.float32) * c_hop
        return t_inj, t_arr, gbus, lbus
    raise ValueError(f"beacon_tx is undefined for topology {topo.kind!r}")


def _set1(arr, i, val):
    """arr.at[i].set(val) as a one-hot select (row update for ndim > 1).
    Vmap-safe and scatter-free; the single shared copy — repro.core.sim
    aliases it (see the rationale comment there)."""
    hot = jnp.arange(arr.shape[0]) == i
    return jnp.where(hot.reshape((-1,) + (1,) * (arr.ndim - 1)), val, arr)


# ==========================================================================
# Wall-clock host analog (serving.engine.FleetSim).
#
# The serving engine has no tick-granular bus occupancy; the analog is a
# stateless per-receiver delay vector with the same *shape* as the
# tick-domain fabric: shared_bus serializes receivers, hier_tree pays a
# fixed two-hop crossing, mesh2d pays hop-count latency.  ``ideal``
# returns all-zero delays, which FleetSim treats as instant delivery —
# exactly the pre-transport `_broadcast` fan-out.
# ==========================================================================

def host_beacon_delays(kind: str, k: int, src: int, *, c_b: float = 1.0,
                       c_hop: float = 0.5) -> np.ndarray:
    """(k,) wall-clock beacon delivery delays from ``src`` per receiver
    (entry ``src`` is 0 and unused)."""
    if kind not in TOPOLOGIES:
        raise ValueError(f"unknown topology {kind!r}; "
                         f"choose from {TOPOLOGIES}")
    d = np.zeros(k, np.float64)
    if kind == "ideal" or k <= 1:
        return d
    if kind == "shared_bus":
        rank = (np.arange(k) - src) % k              # own-first order
        d = rank * c_b
    elif kind == "hier_tree":
        d = np.full(k, 2.0 * c_b)                    # global + local hop
    elif kind == "mesh2d":
        d = c_b + mesh_hops(k)[src] * c_hop
    d[src] = 0.0
    return d
