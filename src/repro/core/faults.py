"""Fault injection for the management fabric (DESIGN.md §13).

The paper evaluates the clustered manager on a *static* fabric; a
production run-time must also stay up when links and manager nodes fail
(ROADMAP: "Fault and churn scenarios — dynamic topologies").  This
module makes the fabric mutable under events without adding a single
static axis: faults live in two traced state leaves

  ``link_up``    (k, k) f32 directed link mask, 1 = up
  ``gmn_alive``  (k,)  f32 GMN liveness vector, 1 = alive

mutated by four event types the simulator already knows how to order
(``EV_LINK_DOWN`` / ``EV_LINK_UP`` / ``EV_GMN_FAIL`` / ``EV_GMN_HEAL``,
repro.core.sim).  The *schedule* of fault events is a pytree of traced
arrays (:class:`FaultSchedule`), so a grid of failure seeds or fault
intensities re-uses one compiled XLA program exactly like a knob grid —
only the schedule *length* (a shape) recompiles.

Declarative front-end: a :class:`FaultSpec` names a generator and its
parameters (hashable, like ``WorkloadSpec``) and ``build(k, sim_len)``
expands it host-side into a schedule with NumPy determinism — the same
(spec, k, sim_len) always builds the same schedule, which is what the
chaos tests' bitwise-reproducibility contract rides on.

Generators:

  ``none``           empty schedule — the fault machinery compiled in
                     with zero events.  This is the bitwise no-fault
                     anchor: with every link up and every GMN alive, all
                     fault-aware code paths reduce to exact no-ops and
                     the frozen PR-2/PR-4 goldens reproduce bitwise
                     (tests/test_faults.py).
  ``poisson_links``  seeded Poisson directed-link failures, each
                     repaired after ``repair`` ticks.  Schedule length
                     is the static ``max_events`` bound (padded with
                     INF), so a seed grid never recompiles.
  ``partition``      scheduled fabric partition: every link crossing
                     the cut between the first ``ceil(k * frac)`` GMNs
                     and the rest goes down at ``t_down`` and (unless
                     ``t_heal`` is None) heals at ``t_heal`` —
                     partition-and-heal on any topology.
  ``gmn_churn``      seeded Poisson GMN failures with repair; a failed
                     cluster's pending work re-homes to the live GMN
                     with the least total load (``min_search``
                     takeover, repro.core.sim._takeover).  GMN 0 is
                     never churned — it anchors the hot-spare pool so a
                     live takeover target always exists.
  ``scripted``       explicit (t, kind, a0, a1) event tuples for
                     hand-built chaos scenarios and unit tests.

Semantics of an injected fault (full per-topology discussion in
DESIGN.md §13):

  - beacons are *best-effort*: a beacon injected while the (src, rcv)
    link is down or the receiver is dead is dropped and counted in
    ``msgs_lost``; loss is decided at injection time (in-flight
    messages already left the source and complete).
  - task-start groups and join-exit forwards are *reliable*: a down
    link costs a detour/retransmit penalty (2 extra hops: ``2 * c_hop``
    on mesh2d, one extra serialized grant pair ``2 * c_b`` elsewhere)
    counted in ``reroutes`` — management work is never silently lost,
    so every started application still completes under faults.
  - ``downtime`` accumulates the completed outage durations of links
    and GMNs (accounted at the heal event; outages still open at the
    end of the run are not counted).
  - overlapping failures of the same link/GMN merge (handlers are
    idempotent; the first heal re-raises the resource).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

# fault kinds inside a schedule; the simulator maps them onto event
# types EV_LINK_DOWN..EV_GMN_HEAL = 4..7 (repro.core.sim)
F_LINK_DOWN = 0
F_LINK_UP = 1
F_GMN_FAIL = 2
F_GMN_HEAL = 3

FAULT_EVENT_NAMES = ("link_down", "link_up", "gmn_fail", "gmn_heal")

FAULT_KINDS = ("none", "poisson_links", "partition", "gmn_churn",
               "scripted")

_INF = np.float32(1e18)          # the shared queue sentinel (eventq.INF)


class FaultSchedule(NamedTuple):
    """Traced fault schedule: four (F,) leaves, INF-padded.  A pytree —
    swapping schedules of the same length re-uses the compiled program
    (the no-recompile contract the fault_frontier claim gates)."""
    times: jnp.ndarray           # (F,) f32 event times, INF = padding
    kinds: jnp.ndarray           # (F,) i32 F_LINK_DOWN..F_GMN_HEAL
    a0: jnp.ndarray              # (F,) i32 link src / failed GMN
    a1: jnp.ndarray              # (F,) i32 link dst / unused

    @property
    def capacity(self) -> int:
        return int(self.times.shape[0])


def _schedule(events, pad: int) -> FaultSchedule:
    """Build an INF-padded FaultSchedule from (t, kind, a0, a1) tuples.

    ``pad`` must be a deterministic function of the *spec* (never of the
    drawn randomness) so every seed in a grid produces the same shapes.
    """
    events = sorted(events, key=lambda e: (e[0], e[1], e[2], e[3]))
    if len(events) > pad:
        raise ValueError(f"fault schedule needs {len(events)} slots but "
                         f"pad={pad}; raise max_events")
    n = max(pad, len(events))
    times = np.full((n,), _INF, np.float32)
    kinds = np.zeros((n,), np.int32)
    a0 = np.zeros((n,), np.int32)
    a1 = np.zeros((n,), np.int32)
    for i, (t, kind, x, y) in enumerate(events):
        times[i] = t
        kinds[i] = kind
        a0[i] = x
        a1[i] = y
    return FaultSchedule(jnp.asarray(times), jnp.asarray(kinds),
                         jnp.asarray(a0), jnp.asarray(a1))


@dataclass(frozen=True)
class FaultSpec:
    """Declarative, hashable fault scenario (the ``faults`` axis of
    ``ExperimentSpec``).  ``params`` is a sorted tuple of (name, value)
    pairs so equal specs hash equal; use the classmethod constructors."""
    kind: str = "none"
    params: tuple = ()
    seed: int = 0
    name: str = ""               # display label; defaults to kind

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"choose from {FAULT_KINDS}")

    # -- constructors -------------------------------------------------

    @classmethod
    def none(cls) -> "FaultSpec":
        """Fault machinery compiled in, zero events (the bitwise
        no-fault anchor)."""
        return cls()

    @classmethod
    def poisson_links(cls, rate: float = 1e-4, repair: float = 20_000.0,
                      seed: int = 0, max_events: int = 32,
                      symmetric: bool = True, name: str = "") -> "FaultSpec":
        """Directed links fail as a Poisson process with ``rate``
        failures per tick fabric-wide; each failed link heals after
        ``repair`` ticks.  ``max_events`` bounds the schedule length
        statically (a seed grid keeps one compiled program)."""
        return cls(kind="poisson_links", seed=int(seed),
                   name=name or "poisson_links",
                   params=(("max_events", int(max_events)),
                           ("rate", float(rate)),
                           ("repair", float(repair)),
                           ("symmetric", bool(symmetric))))

    @classmethod
    def partition(cls, t_down: float, t_heal: float | None = None,
                  frac: float = 0.5, name: str = "") -> "FaultSpec":
        """Cut the fabric in two at ``t_down`` (first ``ceil(k * frac)``
        GMNs vs the rest, both link directions), heal at ``t_heal``."""
        return cls(kind="partition", name=name or "partition",
                   params=(("frac", float(frac)),
                           ("t_down", float(t_down)),
                           ("t_heal",
                            None if t_heal is None else float(t_heal))))

    @classmethod
    def gmn_churn(cls, rate: float = 1e-5, repair: float = 30_000.0,
                  seed: int = 0, max_events: int = 8,
                  name: str = "") -> "FaultSpec":
        """GMNs fail as a Poisson process and heal after ``repair``
        ticks.  GMN 0 never fails (hot-spare anchor), so ``min_search``
        takeover always finds a live manager."""
        return cls(kind="gmn_churn", seed=int(seed),
                   name=name or "gmn_churn",
                   params=(("max_events", int(max_events)),
                           ("rate", float(rate)),
                           ("repair", float(repair))))

    @classmethod
    def scripted(cls, events, name: str = "") -> "FaultSpec":
        """Explicit schedule: (t, "link_down"|"link_up"|"gmn_fail"|
        "gmn_heal", a0, a1) tuples."""
        norm = []
        for t, kind, x, y in events:
            if kind not in FAULT_EVENT_NAMES:
                raise ValueError(f"unknown fault event {kind!r}; "
                                 f"choose from {FAULT_EVENT_NAMES}")
            norm.append((float(t), str(kind), int(x), int(y)))
        return cls(kind="scripted", name=name or "scripted",
                   params=(("events", tuple(norm)),))

    # -- expansion ----------------------------------------------------

    @property
    def p(self) -> dict:
        return dict(self.params)

    @property
    def label(self) -> str:
        return self.name or self.kind

    def build(self, k: int, sim_len: float) -> FaultSchedule:
        """Expand into a traced schedule for a k-GMN fabric.
        Deterministic: same (spec, k, sim_len) -> same schedule."""
        d = self.p
        if self.kind == "none":
            return _schedule([], 0)
        if self.kind == "poisson_links":
            return self._poisson_links(k, sim_len, d)
        if self.kind == "partition":
            return self._partition(k, d)
        if self.kind == "gmn_churn":
            return self._gmn_churn(k, sim_len, d)
        # scripted
        ev = [(t, FAULT_EVENT_NAMES.index(kind), x, y)
              for t, kind, x, y in d["events"]]
        for t, kind, x, y in ev:
            hi = k if kind >= F_GMN_FAIL else k
            if not (0 <= x < k) or not (0 <= y <= hi):
                raise ValueError(f"fault target ({x}, {y}) out of range "
                                 f"for k={k}")
        return _schedule(ev, len(ev))

    def _poisson_links(self, k, sim_len, d):
        per = 4 if d["symmetric"] else 2
        pad = d["max_events"] * per
        if k < 2 or d["rate"] <= 0:
            return _schedule([], pad)
        rng = np.random.RandomState(self.seed)
        events, t = [], 0.0
        for _ in range(d["max_events"]):
            t += rng.exponential(1.0 / d["rate"])
            if t >= sim_len:
                break
            i = int(rng.randint(k))
            j = int(rng.randint(k - 1))
            j += j >= i                              # j != i
            pairs = [(i, j), (j, i)] if d["symmetric"] else [(i, j)]
            for a, b in pairs:
                events.append((t, F_LINK_DOWN, a, b))
                events.append((t + d["repair"], F_LINK_UP, a, b))
        return _schedule(events, pad)

    def _partition(self, k, d):
        a = max(1, int(np.ceil(k * d["frac"])))
        left = range(min(a, k))
        right = range(min(a, k), k)
        events = []
        for i in left:
            for j in right:
                for s, t_ in ((i, j), (j, i)):
                    events.append((d["t_down"], F_LINK_DOWN, s, t_))
                    if d["t_heal"] is not None:
                        events.append((d["t_heal"], F_LINK_UP, s, t_))
        return _schedule(events, len(events))

    def _gmn_churn(self, k, sim_len, d):
        pad = d["max_events"] * 2
        if k < 2 or d["rate"] <= 0:
            return _schedule([], pad)                # GMN 0 is protected
        rng = np.random.RandomState(self.seed)
        events, t = [], 0.0
        for _ in range(d["max_events"]):
            t += rng.exponential(1.0 / d["rate"])
            if t >= sim_len:
                break
            g = int(rng.randint(1, k))               # never GMN 0
            events.append((t, F_GMN_FAIL, g, 0))
            events.append((t + d["repair"], F_GMN_HEAL, g, 0))
        return _schedule(events, pad)

    # -- serialization (ExperimentSpec payloads, schema v5) -----------

    def to_dict(self) -> dict:
        d = {"kind": self.kind, "seed": self.seed, "name": self.name}
        params = {}
        for key, val in self.params:
            if key == "events":
                val = [list(e) for e in val]
            params[key] = val
        d["params"] = params
        return d

    @staticmethod
    def from_dict(d: dict) -> "FaultSpec":
        unknown = set(d) - {"kind", "seed", "name", "params"}
        if unknown:
            raise ValueError(
                f"unknown FaultSpec fields {sorted(unknown)}; this reader "
                f"supports fields ['kind', 'name', 'params', 'seed']")
        params = []
        for key, val in sorted(dict(d.get("params", {})).items()):
            if key == "events":
                val = tuple(tuple(e) for e in val)
            params.append((key, val))
        return FaultSpec(kind=d.get("kind", "none"),
                         params=tuple(params),
                         seed=int(d.get("seed", 0)),
                         name=d.get("name", ""))


DEFAULT_FAULTS = FaultSpec.none()


def pad_to(sched: FaultSchedule, capacity: int) -> FaultSchedule:
    """INF-pad a schedule out to ``capacity`` slots.

    A shape-only change — padded rows carry ``times = INF`` and are
    masked off before they ever reach the queue (``sim._push_faults``) —
    so an ``ExperimentSpec`` fault axis mixing generators with different
    natural lengths can share one compiled program per static combo."""
    n = sched.capacity
    if capacity < n:
        raise ValueError(f"cannot pad a {n}-slot schedule down to "
                         f"{capacity}")
    if capacity == n:
        return sched
    pad = capacity - n
    return FaultSchedule(
        jnp.concatenate([sched.times, jnp.full((pad,), _INF, jnp.float32)]),
        jnp.concatenate([sched.kinds, jnp.zeros((pad,), jnp.int32)]),
        jnp.concatenate([sched.a0, jnp.zeros((pad,), jnp.int32)]),
        jnp.concatenate([sched.a1, jnp.zeros((pad,), jnp.int32)]))


def as_schedule(faults, k: int, sim_len: float):
    """Normalize None | FaultSpec | FaultSchedule to None | FaultSchedule."""
    if faults is None:
        return None
    if isinstance(faults, FaultSpec):
        return faults.build(k, sim_len)
    return faults
