"""Workload/stimulus generators for the TLM simulator (paper Sec 5.3/5.4).

- independent_tasks: one application of n equal/uniform childs (Fig 2).
- interference: two competing application streams, Poisson intra-pair
  offset lambda=7999, periodic pair launches (Fig 3/4, Table 5).

The paper does not publish the pair period; we launch a pair every
``pair_period`` ticks (default 2*lambda, keeping offered load < 1 and the
stimulus active ~90% of sim time as in Sec 5.4).  Deviation documented in
DESIGN.md §8.
"""
from __future__ import annotations

import numpy as np

from repro.core.sim import SimParams

INF = 1e18
MAX_LEN = 16_000.0


def independent_tasks(p: SimParams, *, n_apps: int = 1, length=MAX_LEN,
                      seed: int = 0):
    """Single application(s) of n_childs equal-length tasks (Fig 2b)."""
    rng = np.random.default_rng(seed)
    arrivals = np.full((p.max_apps,), INF, np.float32)
    gmns = np.zeros((p.max_apps,), np.int32)
    arrivals[:n_apps] = np.arange(n_apps) * 1e6
    gmns[:n_apps] = rng.integers(0, p.k, n_apps)
    lengths = np.full((p.max_apps, p.n_childs), length, np.float32)
    return arrivals, gmns, lengths


def interference(p: SimParams, *, sim_len: float = 2e6, lam: float = 7_999.0,
                 pair_period: float | None = None, seed: int = 0,
                 active_frac: float = 0.9):
    """Two competing streams (Fig 4): pairs arrive periodically; the second
    app of each pair is offset by Poisson(lambda); child lengths uniform in
    95-100% of MAX_LEN; stimulus targets a random GMN with highest prio.

    Default pair_period=14000 is CALIBRATED so the centralized (k=1)
    manager saturates as in the paper (k=16/k=1 speedup ratio ~2.8,
    Table 5); the paper does not publish its stimulus period — see
    EXPERIMENTS.md §Fig3a for the calibration sweep."""
    rng = np.random.default_rng(seed)
    if pair_period is None:
        pair_period = 14_000.0
    horizon = active_frac * sim_len
    n_pairs = int(horizon / pair_period)
    n_apps = min(2 * n_pairs, p.max_apps - 2)

    arrivals = np.full((p.max_apps,), INF, np.float32)
    gmns = np.zeros((p.max_apps,), np.int32)
    i = 0
    t = 0.0
    while i + 1 < n_apps:
        arrivals[i] = t
        offset = rng.exponential(lam)
        arrivals[i + 1] = t + offset
        gmns[i] = rng.integers(0, p.k)
        gmns[i + 1] = rng.integers(0, p.k)
        i += 2
        t += pair_period
    lengths = rng.uniform(0.95 * MAX_LEN, MAX_LEN,
                          (p.max_apps, p.n_childs)).astype(np.float32)
    return arrivals, gmns, lengths


def _stack(workloads):
    arrs, gmns, lens = zip(*workloads)
    return (np.stack(arrs), np.stack(gmns), np.stack(lens))


def interference_batch(p: SimParams, *, seeds=(0,), sim_len: float = 2e6,
                       lam: float = 7_999.0, pair_period: float | None = None,
                       active_frac: float = 0.9):
    """Stack of interference workloads over seeds, shaped for
    ``repro.core.sweep``: arrivals (S, A), gmns (S, A), lengths (S, A, n)."""
    return _stack([interference(p, sim_len=sim_len, lam=lam,
                                pair_period=pair_period, seed=s,
                                active_frac=active_frac)
                   for s in seeds])


def interference_grid(p: SimParams, *, pair_periods, seeds=(0,),
                      sim_len: float = 2e6, lam: float = 7_999.0,
                      active_frac: float = 0.9):
    """Interference workloads over a (pair_period x seed) grid, flattened
    row-major (pair_period outermost) into the seed axis S for a single
    ``sweep`` call; reshape results to (len(pair_periods), len(seeds))."""
    return _stack([interference(p, sim_len=sim_len, lam=lam, pair_period=pp,
                                seed=s, active_frac=active_frac)
                   for pp in pair_periods for s in seeds])


def independent_batch(p: SimParams, *, seeds=(0,), n_apps: int = 1,
                      length=MAX_LEN):
    """Stack of independent-task workloads over seeds (sweep-shaped)."""
    return _stack([independent_tasks(p, n_apps=n_apps, length=length, seed=s)
                   for s in seeds])


def offered_load(p: SimParams, pair_period: float, mean_len=0.975 * MAX_LEN):
    """Utilization sanity check: must stay < 1 for a stable system."""
    work_per_period = 2 * p.n_childs * mean_len
    return work_per_period / (pair_period * p.m)
