"""Workload/stimulus generators for the TLM simulator (paper Sec 5.3/5.4,
plus scenario extensions beyond the paper for the policy design space).

Paper stimuli:

- independent_tasks: one application of n equal/uniform childs (Fig 2).
- interference: two competing application streams, Poisson intra-pair
  offset lambda=7999, periodic pair launches (Fig 3/4, Table 5).

Scenario extensions (exercise the mapping/beacon policies of
``core/policies.py`` under non-Poisson conditions):

- bursty: MMPP-2 arrivals — a hidden ON/OFF Markov chain modulates the
  Poisson rate, producing arrival bursts that stress beacon staleness.
- hotspot: skewed stimulus entry — a fraction of all applications arrives
  at one hot GMN, stressing the stage-1 policy's ability to spread load
  off a congested entry point.
- heavy_tail_lengths / length_dist="pareto": Pareto child task lengths
  (a few stragglers dominate), stressing the join barrier.

The paper does not publish the pair period; we launch a pair every
``pair_period`` ticks (default 2*lambda, keeping offered load < 1 and the
stimulus active ~90% of sim time as in Sec 5.4).  Deviation documented in
DESIGN.md §8.
"""
from __future__ import annotations

import numpy as np

from repro.core.sim import SimParams

INF = 1e18
MAX_LEN = 16_000.0
# calibrated default stimulus period (see interference()'s docstring);
# the single source of truth — experiment.WorkloadSpec records it as
# the effective lane metadata when pair_period is left unset
DEFAULT_PAIR_PERIOD = 14_000.0


def independent_tasks(p: SimParams, *, n_apps: int = 1, length=MAX_LEN,
                      seed: int = 0):
    """Single application(s) of n_childs equal-length tasks (Fig 2b)."""
    rng = np.random.default_rng(seed)
    arrivals = np.full((p.max_apps,), INF, np.float32)
    gmns = np.zeros((p.max_apps,), np.int32)
    arrivals[:n_apps] = np.arange(n_apps) * 1e6
    gmns[:n_apps] = rng.integers(0, p.k, n_apps)
    lengths = np.full((p.max_apps, p.n_childs), length, np.float32)
    return arrivals, gmns, lengths


def interference(p: SimParams, *, sim_len: float = 2e6, lam: float = 7_999.0,
                 pair_period: float | None = None, seed: int = 0,
                 active_frac: float = 0.9):
    """Two competing streams (Fig 4): pairs arrive periodically; the second
    app of each pair is offset by Poisson(lambda); child lengths uniform in
    95-100% of MAX_LEN; stimulus targets a random GMN with highest prio.

    Default pair_period=14000 is CALIBRATED so the centralized (k=1)
    manager saturates as in the paper (k=16/k=1 speedup ratio ~2.8,
    Table 5); the paper does not publish its stimulus period — see
    EXPERIMENTS.md §Fig3a for the calibration sweep."""
    rng = np.random.default_rng(seed)
    if pair_period is None:
        pair_period = DEFAULT_PAIR_PERIOD
    horizon = active_frac * sim_len
    n_pairs = int(horizon / pair_period)
    n_apps = min(2 * n_pairs, p.max_apps - 2)

    arrivals = np.full((p.max_apps,), INF, np.float32)
    gmns = np.zeros((p.max_apps,), np.int32)
    i = 0
    t = 0.0
    while i + 1 < n_apps:
        arrivals[i] = t
        offset = rng.exponential(lam)
        arrivals[i + 1] = t + offset
        gmns[i] = rng.integers(0, p.k)
        gmns[i + 1] = rng.integers(0, p.k)
        i += 2
        t += pair_period
    lengths = rng.uniform(0.95 * MAX_LEN, MAX_LEN,
                          (p.max_apps, p.n_childs)).astype(np.float32)
    return arrivals, gmns, lengths


def heavy_tail_lengths(p: SimParams, rng, *, alpha: float = 1.5,
                       scale: float = 0.2 * MAX_LEN,
                       cap: float = 8 * MAX_LEN) -> np.ndarray:
    """Pareto(alpha) child task lengths: scale*(1+Pareto), capped.  At the
    default alpha=1.5 the mean is 3*scale (=0.6*MAX_LEN) but a few childs
    run ~cap ticks — the join barrier waits on stragglers."""
    ln = scale * (1.0 + rng.pareto(alpha, (p.max_apps, p.n_childs)))
    return np.minimum(ln, cap).astype(np.float32)


def _lengths(p: SimParams, rng, dist: str) -> np.ndarray:
    if dist == "uniform":
        return rng.uniform(0.95 * MAX_LEN, MAX_LEN,
                           (p.max_apps, p.n_childs)).astype(np.float32)
    if dist == "pareto":
        return heavy_tail_lengths(p, rng)
    raise ValueError(f"unknown length_dist {dist!r}; "
                     "choose from ('uniform', 'pareto')")


def bursty(p: SimParams, *, sim_len: float = 2e6, iat_on: float = 4_000.0,
           iat_off: float = 56_000.0, sojourn_on: float = 1e5,
           sojourn_off: float = 2e5, seed: int = 0,
           active_frac: float = 0.9, length_dist: str = "uniform"):
    """MMPP-2 (Markov-modulated Poisson) stimulus: a hidden two-state
    chain with exponential sojourns modulates the arrival rate between a
    burst phase (mean inter-arrival ``iat_on``) and a lull (``iat_off``).
    Each application targets a uniform random GMN."""
    rng = np.random.default_rng(seed)
    horizon = active_frac * sim_len
    arrivals = np.full((p.max_apps,), INF, np.float32)
    gmns = np.zeros((p.max_apps,), np.int32)
    i = 0
    t = 0.0
    on = True
    phase_end = rng.exponential(sojourn_on)
    while t < horizon and i < p.max_apps:
        gap = rng.exponential(iat_on if on else iat_off)
        if t + gap >= phase_end:
            t = phase_end
            on = not on
            phase_end = t + rng.exponential(sojourn_on if on else sojourn_off)
            continue
        t += gap
        arrivals[i] = t
        gmns[i] = rng.integers(0, p.k)
        i += 1
    return arrivals, gmns, _lengths(p, rng, length_dist)


def hotspot(p: SimParams, *, sim_len: float = 2e6, mean_iat: float = 7_000.0,
            hot_frac: float = 0.75, hot_gmn: int = 0, seed: int = 0,
            active_frac: float = 0.9, length_dist: str = "uniform"):
    """Skewed stimulus entry: Poisson arrivals (mean inter-arrival
    ``mean_iat``) of which a ``hot_frac`` fraction enters at ``hot_gmn``;
    the rest spread uniformly.  Stage-1 policies that respect the view
    spill work off the hot cluster; oblivious ones pile onto it."""
    if not 0 <= hot_gmn < p.k:
        raise ValueError(f"hot_gmn {hot_gmn} out of range for k={p.k}")
    rng = np.random.default_rng(seed)
    horizon = active_frac * sim_len
    arrivals = np.full((p.max_apps,), INF, np.float32)
    gmns = np.zeros((p.max_apps,), np.int32)
    i = 0
    t = 0.0
    while i < p.max_apps:
        t += rng.exponential(mean_iat)
        if t >= horizon:
            break
        arrivals[i] = t
        gmns[i] = hot_gmn if rng.random() < hot_frac \
            else int(rng.integers(0, p.k))
        i += 1
    return arrivals, gmns, _lengths(p, rng, length_dist)


def _stack(workloads):
    arrs, gmns, lens = zip(*workloads)
    return (np.stack(arrs), np.stack(gmns), np.stack(lens))


def interference_batch(p: SimParams, *, seeds=(0,), sim_len: float = 2e6,
                       lam: float = 7_999.0, pair_period: float | None = None,
                       active_frac: float = 0.9):
    """Stack of interference workloads over seeds, shaped for
    ``repro.core.sweep``: arrivals (S, A), gmns (S, A), lengths (S, A, n)."""
    return _stack([interference(p, sim_len=sim_len, lam=lam,
                                pair_period=pair_period, seed=s,
                                active_frac=active_frac)
                   for s in seeds])


def interference_grid(p: SimParams, *, pair_periods, seeds=(0,),
                      sim_len: float = 2e6, lam: float = 7_999.0,
                      active_frac: float = 0.9):
    """Interference workloads over a (pair_period x seed) grid, flattened
    row-major (pair_period outermost) into the seed axis S for a single
    ``sweep`` call; reshape results to (len(pair_periods), len(seeds))."""
    return _stack([interference(p, sim_len=sim_len, lam=lam, pair_period=pp,
                                seed=s, active_frac=active_frac)
                   for pp in pair_periods for s in seeds])


def bursty_batch(p: SimParams, *, seeds=(0,), sim_len: float = 2e6,
                 length_dist: str = "uniform", **kw):
    """Stack of MMPP workloads over seeds (sweep-shaped)."""
    return _stack([bursty(p, sim_len=sim_len, seed=s,
                          length_dist=length_dist, **kw) for s in seeds])


def hotspot_batch(p: SimParams, *, seeds=(0,), sim_len: float = 2e6,
                  hot_frac: float = 0.75, length_dist: str = "uniform",
                  **kw):
    """Stack of hotspot workloads over seeds (sweep-shaped)."""
    return _stack([hotspot(p, sim_len=sim_len, hot_frac=hot_frac, seed=s,
                           length_dist=length_dist, **kw) for s in seeds])


def independent_batch(p: SimParams, *, seeds=(0,), n_apps: int = 1,
                      length=MAX_LEN):
    """Stack of independent-task workloads over seeds (sweep-shaped)."""
    return _stack([independent_tasks(p, n_apps=n_apps, length=length, seed=s)
                   for s in seeds])


def offered_load(p: SimParams, pair_period: float, mean_len=0.975 * MAX_LEN):
    """Utilization sanity check: must stay < 1 for a stable system."""
    work_per_period = 2 * p.n_childs * mean_len
    return work_per_period / (pair_period * p.m)
