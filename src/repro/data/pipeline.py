"""Deterministic sharded synthetic LM data pipeline.

Tokens are a pure function of (seed, shard, step) via threefry — any host
can regenerate any shard, which is what makes straggler takeover and
elastic restarts trivial: there is no data-server state to rebuild, only
the step counter from the checkpoint.

A background prefetch thread keeps ``depth`` batches ready.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    shard: int = 0               # this host's shard index
    num_shards: int = 1


def synth_batch(cfg: ModelConfig, batch: int, seq: int, dc: DataConfig,
                step: int):
    """Deterministic (seed, shard, step) -> {tokens, labels[, frontends]}."""
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(dc.seed), dc.shard), step)
    S_text = seq - (cfg.vision_tokens if cfg.frontend == "vision" else 0)
    # zipf-ish skew: squared uniform maps to low token ids more often
    u = jax.random.uniform(key, (batch, S_text + 1))
    toks = (u * u * (cfg.vocab_size - 1)).astype(jnp.int32)
    out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.frontend == "vision":
        out["patches"] = jax.random.normal(
            jax.random.fold_in(key, 1),
            (batch, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        out["frames"] = jax.random.normal(
            jax.random.fold_in(key, 2),
            (batch, cfg.enc_seq_len, cfg.d_model), jnp.bfloat16)
    return out


class DataIterator:
    """Checkpointable, prefetching iterator over synthetic shards."""

    def __init__(self, cfg: ModelConfig, batch: int, seq: int,
                 dc: DataConfig = DataConfig(), start_step: int = 0,
                 depth: int = 2):
        self.cfg, self.batch, self.seq, self.dc = cfg, batch, seq, dc
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._fill_from = start_step
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        s = self._fill_from
        while not self._stop.is_set():
            b = jax.device_get(
                synth_batch(self.cfg, self.batch, self.seq, self.dc, s))
            try:
                self._q.put((s, b), timeout=0.5)
                s += 1
            except queue.Full:
                if self._stop.is_set():
                    return

    def __next__(self):
        while True:
            s, b = self._q.get()
            if s == self.step:                 # drop stale prefetches after restore
                self.step += 1
                return {k: jnp.asarray(v) for k, v in b.items()}
            if s > self.step:                  # shouldn't happen; regenerate
                return self._regen()

    def _regen(self):
        b = synth_batch(self.cfg, self.batch, self.seq, self.dc, self.step)
        self.step += 1
        return b

    def state_dict(self):
        return {"step": self.step}

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
