"""Continuous-batching serving engine managed by the paper's clustered
task manager.

Topology (DESIGN.md §2): the fleet is k clusters (pods / mesh slices); each
cluster scheduler owns its device groups' exact load table and a
beacon-synced view of remote clusters.  A request is placed in two stages —
stage 1 picks the cluster by min-search over the (possibly stale) views,
stage 2 picks the device group by min-search over the exact local table —
and never migrates (map-once, Sec 4.1).  Cluster schedulers exchange
``status-beacon`` messages only when their load drifted by >= dn_th
(Sec 4.2), so scheduler chatter is O(load-change/dn_th), not O(requests).

The engine below is the *control plane*; the data plane (model decode
steps) runs through launch/steps.py.  `FleetSim` wires k schedulers +
worker groups for the host-level simulation used in examples/ and tests;
on a real fleet each ClusterScheduler runs on its pod's coordinator.

Fault tolerance: a dead worker group's in-flight requests re-enter the
global queue (map-once applies to healthy placement, not failure
recovery); its load column is tombstoned so min-search never picks it.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core import beacons as B
from repro.core.messages import Message, MsgType, beacon, task_start


@dataclass(order=True)
class Request:
    sort_key: float
    rid: int = field(compare=False)
    prompt_len: int = field(compare=False, default=128)
    max_new: int = field(compare=False, default=64)
    arrived: float = field(compare=False, default=0.0)
    # filled by the engine
    cluster: int = field(compare=False, default=-1)
    group: int = field(compare=False, default=-1)
    done: int = field(compare=False, default=0)
    finished_at: float = field(compare=False, default=-1.0)


def request_cost(req: Request) -> float:
    """Load contribution of a request (decode slots + prefill amortized)."""
    return 1.0 + req.prompt_len / 4096.0


class ClusterScheduler:
    """One GMN: exact local (groups,) load table + stale remote summaries."""

    def __init__(self, cluster_id: int, k: int, n_groups: int, dn_th: int):
        self.cid = cluster_id
        self.k = k
        self.n_groups = n_groups
        self.dn_th = dn_th
        self.local = np.zeros(n_groups, np.float64)
        self.remote = np.zeros(k, np.float64)     # beacon view (self exact)
        self.last_bcast = 0.0
        self.alive = np.ones(n_groups, bool)
        self.tx_log: list[Message] = []

    # -- stage 2: exact local min-search ------------------------------------
    def place_local(self, req: Request) -> int:
        masked = np.where(self.alive, self.local, np.inf)
        g = int(np.argmin(masked))
        self.local[g] += request_cost(req)
        req.cluster, req.group = self.cid, g
        self.tx_log.append(task_start(self.cid, g, req.rid, 0))
        return g

    def release(self, req: Request):
        self.local[req.group] -= request_cost(req)

    def total_load(self) -> float:
        return float(self.local[self.alive].sum())

    # -- threshold beacons ---------------------------------------------------
    def maybe_beacon(self) -> Optional[Message]:
        load = self.total_load()
        if abs(load - self.last_bcast) >= self.dn_th and self.k > 1:
            self.last_bcast = load
            msg = beacon(self.cid, int(load))
            self.tx_log.append(msg)
            return msg
        return None

    def recv_beacon(self, msg: Message):
        self.remote[msg.src] = msg.data[0]

    def kill_group(self, g: int):
        self.alive[g] = False
        self.local[g] = 0.0

    # -- stage 1: cluster choice over (stale) views --------------------------
    def pick_cluster(self) -> int:
        view = self.remote.copy()
        view[self.cid] = self.total_load()         # own view exact
        order = (np.arange(self.k) + self.cid) % self.k
        return int(order[int(np.argmin(view[order]))])


class FleetSim:
    """k cluster schedulers + simple decode-rate worker model.

    Used by examples/serve_clustered.py and tests to exercise the control
    plane end-to-end (placement quality, beacon volume, failure recovery)
    without TPU hardware."""

    def __init__(self, k: int = 4, groups_per_cluster: int = 8,
                 dn_th: int = 4, tokens_per_tick: float = 8.0):
        self.k = k
        self.schedulers = [ClusterScheduler(c, k, groups_per_cluster, dn_th)
                           for c in range(k)]
        self.tokens_per_tick = tokens_per_tick
        self.active: dict[int, list[Request]] = {}
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self.beacons_tx = 0
        self.t = 0.0
        self._counter = itertools.count()

    def submit(self, req: Request, via_cluster: Optional[int] = None):
        entry = via_cluster if via_cluster is not None \
            else next(self._counter) % self.k
        sched = self.schedulers[entry]
        target = sched.pick_cluster()               # stage 1 (stale view ok)
        tsched = self.schedulers[target]
        g = tsched.place_local(req)                 # stage 2 (exact)
        self.active.setdefault(target * 1000 + g, []).append(req)
        self._broadcast(tsched)

    def _broadcast(self, sched: ClusterScheduler):
        msg = sched.maybe_beacon()
        if msg is not None:
            self.beacons_tx += 1
            for s in self.schedulers:
                if s.cid != sched.cid:
                    s.recv_beacon(msg)

    def tick(self, dt: float = 1.0):
        """Advance decode: each group serves its batch at a shared rate."""
        self.t += dt
        for key, reqs in list(self.active.items()):
            c, g = divmod(key, 1000)
            sched = self.schedulers[c]
            if not sched.alive[g] or not reqs:
                if not reqs:
                    self.active.pop(key)
                continue
            rate = self.tokens_per_tick * dt / max(len(reqs), 1)
            still = []
            for r in reqs:
                r.done += rate
                if r.done >= r.max_new:
                    r.finished_at = self.t
                    sched.release(r)
                    self.finished.append(r)
                else:
                    still.append(r)
            if still:
                self.active[key] = still
            else:
                self.active.pop(key)
            self._broadcast(sched)

    def kill(self, cluster: int, group: int):
        """Fail a worker group: requeue its in-flight requests elsewhere."""
        sched = self.schedulers[cluster]
        sched.kill_group(group)
        orphans = self.active.pop(cluster * 1000 + group, [])
        self._broadcast(sched)
        for r in orphans:
            r.cluster = r.group = -1
            self.submit(r)
        return len(orphans)

    def loads(self) -> np.ndarray:
        return np.stack([s.local for s in self.schedulers])

    def imbalance(self) -> float:
        l = self.loads()
        alive = np.stack([s.alive for s in self.schedulers])
        vals = l[alive]
        return float(vals.max() / max(vals.mean(), 1e-9)) if vals.size else 0.0
