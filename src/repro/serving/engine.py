"""Continuous-batching serving engine managed by the paper's clustered
task manager.

Topology (DESIGN.md §2): the fleet is k clusters (pods / mesh slices); each
cluster scheduler owns its device groups' exact load table and a
beacon-synced view of remote clusters.  A request is placed in two stages —
stage 1 picks the cluster over the (possibly stale) views, stage 2 picks
the device group by min-search over the exact local table — and never
migrates (map-once, Sec 4.1).  Both decisions and the status-communication
trigger delegate to the pluggable policy core (``core/policies.py``,
DESIGN.md §9) through its wall-clock numpy adapters: with the default
``min_search`` + ``threshold`` pair, schedulers exchange ``status-beacon``
messages only when their load drifted by >= dn_th (Sec 4.2), so scheduler
chatter is O(load-change/dn_th), not O(requests); ``periodic``/``hybrid``
beacons and ``round_robin``/``hashed_random``/``staleness_weighted``
mapping run through the same two lines of adapter code.

The engine below is the *control plane*; the data plane (model decode
steps) runs through launch/steps.py.  `FleetSim` wires k schedulers +
worker groups for the host-level simulation used in examples/ and tests;
on a real fleet each ClusterScheduler runs on its pod's coordinator.

Fault tolerance: a dead worker group's in-flight requests re-enter the
global queue (map-once applies to healthy placement, not failure
recovery); its load column is tombstoned so min-search never picks it.
The same contract extends to the management fabric (DESIGN.md §13):
``fail_link``/``heal_link`` drop beacon deliveries on a directed (src,
rcv) link mask, ``fail_gmn``/``heal_gmn`` take a whole cluster
scheduler down — placements that would land on a dead manager re-home
to the least-loaded live one (the ``min_search`` takeover, mirroring
``core/sim._takeover``), beacons from/to it are lost, and the
``msgs_lost`` / ``reroutes`` / ``downtime`` counters account the damage
exactly like the tick-domain simulator's fault leaves.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core import policies as P
from repro.core import transport as T
from repro.core.messages import Message, MsgType, beacon, task_start


@dataclass(order=True)
class Request:
    sort_key: float
    rid: int = field(compare=False)
    prompt_len: int = field(compare=False, default=128)
    max_new: int = field(compare=False, default=64)
    arrived: float = field(compare=False, default=0.0)
    # filled by the engine
    cluster: int = field(compare=False, default=-1)
    group: int = field(compare=False, default=-1)
    done: int = field(compare=False, default=0)
    finished_at: float = field(compare=False, default=-1.0)


def request_cost(req: Request) -> float:
    """Load contribution of a request (decode slots + prefill amortized)."""
    return 1.0 + req.prompt_len / 4096.0


class ClusterScheduler:
    """One GMN: exact local (groups,) load table + stale remote summaries.

    A thin wall-clock adapter over ``core/policies.py``: stage-1/stage-2
    placement and the beacon trigger are the shared policy functions; this
    class only keeps the views, timestamps, and the message log."""

    def __init__(self, cluster_id: int, k: int, n_groups: int, dn_th: int,
                 *, mapping: str = "min_search", beacon: str = "threshold",
                 T_b: float = float("inf")):
        if mapping not in P.MAPPING_POLICIES:
            raise ValueError(f"unknown mapping policy {mapping!r}; "
                             f"choose from {P.MAPPING_POLICIES}")
        if beacon not in P.BEACON_POLICIES:
            raise ValueError(f"unknown beacon policy {beacon!r}; "
                             f"choose from {P.BEACON_POLICIES}")
        if mapping == "staleness_weighted" and not np.isfinite(T_b):
            raise ValueError("staleness_weighted needs a finite T_b: with "
                             "T_b=inf the age penalty is zero and the "
                             "policy degenerates to min_search")
        self.cid = cluster_id
        self.k = k
        self.n_groups = n_groups
        self.dn_th = dn_th
        self.mapping = mapping
        self.beacon = beacon
        self.T_b = T_b
        self.local = np.zeros(n_groups, np.float64)
        self.remote = np.zeros(k, np.float64)     # beacon view (self exact)
        self.remote_t = np.zeros(k, np.float64)   # wall-clock of last receipt
        self.last_bcast = 0.0
        self.last_tx = 0.0
        self.map_ctr = 0                          # round-robin pointer / salt
        self.alive = np.ones(n_groups, bool)
        self.tx_log: list[Message] = []

    # -- stage 2: exact local min-search (core/policies.host_stage2) --------
    def place_local(self, req: Request) -> int:
        g = P.host_stage2(self.local, self.alive)
        self.local[g] += request_cost(req)
        req.cluster, req.group = self.cid, g
        self.tx_log.append(task_start(self.cid, g, req.rid, 0))
        return g

    def release(self, req: Request):
        self.local[req.group] -= request_cost(req)

    def total_load(self) -> float:
        return float(self.local[self.alive].sum())

    # -- status beacons (core/policies.host_beacon_due) ----------------------
    def maybe_beacon(self, now: float = 0.0) -> Optional[Message]:
        load = self.total_load()
        due = P.host_beacon_due(self.beacon, load - self.last_bcast, now,
                                self.last_tx, dn_th=self.dn_th, T_b=self.T_b)
        if due and self.k > 1:
            self.last_bcast = load
            self.last_tx = now
            msg = beacon(self.cid, int(load))
            self.tx_log.append(msg)
            return msg
        return None

    def recv_beacon(self, msg: Message, now: float = 0.0):
        self.remote[msg.src] = msg.data[0]
        self.remote_t[msg.src] = now

    def kill_group(self, g: int):
        self.alive[g] = False
        self.local[g] = 0.0

    # -- stage 1: cluster choice (core/policies.host_pick) -------------------
    def pick_cluster(self, now: float = 0.0, salt: int = 0) -> int:
        view = self.remote.copy()
        view[self.cid] = self.total_load()         # own view exact
        age = now - self.remote_t
        age[self.cid] = 0.0
        c = P.host_pick(self.mapping, view, age, self.cid, self.map_ctr,
                        salt, T_b=self.T_b)
        self.map_ctr += 1
        return c


class FleetSim:
    """k cluster schedulers + simple decode-rate worker model.

    Used by examples/serve_clustered.py and tests to exercise the control
    plane end-to-end (placement quality, beacon volume, failure recovery)
    without TPU hardware.

    Beacon delivery goes through the wall-clock analog of the
    interconnect transport (``core/transport.host_beacon_delays``,
    DESIGN.md §10): under the default ``ideal`` topology every receiver's
    view updates the instant a beacon fires (the historical `_broadcast`
    fan-out); under ``shared_bus`` / ``hier_tree`` / ``mesh2d`` each
    receiver sees the update only after its per-receiver delay, so remote
    views age heterogeneously just like in the tick-domain simulator."""

    def __init__(self, k: int = 4, groups_per_cluster: int = 8,
                 dn_th: int = 4, tokens_per_tick: float = 8.0,
                 *, mapping: str = "min_search", beacon: str = "threshold",
                 T_b: float = float("inf"), topology: str = "ideal",
                 msg_delay: float = 1.0, hop_delay: float = 0.5):
        if topology not in T.TOPOLOGIES:
            raise ValueError(f"unknown topology {topology!r}; "
                             f"choose from {T.TOPOLOGIES}")
        self.k = k
        self.schedulers = [ClusterScheduler(c, k, groups_per_cluster, dn_th,
                                            mapping=mapping, beacon=beacon,
                                            T_b=T_b)
                           for c in range(k)]
        self.tokens_per_tick = tokens_per_tick
        self.topology = topology
        self.msg_delay = msg_delay      # wall-clock analog of c_b
        self.hop_delay = hop_delay      # wall-clock analog of c_hop
        # keyed by (cluster, group): a composite int key collides silently
        # once a cluster has >= 1000 groups
        self.active: dict[tuple[int, int], list[Request]] = {}
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self.beacons_tx = 0
        self.beacons_rx = 0
        # in-flight beacon deliveries: (deliver_at, seq, receiver, message)
        self.pending: list[tuple[float, int, int, Message]] = []
        self.t = 0.0
        self._counter = itertools.count()
        self._seq = itertools.count()   # heap tie-breaker
        # management-fabric fault state (DESIGN.md §13), the wall-clock
        # analog of the tick-domain link_up/gmn_alive leaves
        self.link_up = np.ones((k, k), bool)
        self.gmn_alive = np.ones(k, bool)
        self.msgs_lost = 0
        self.reroutes = 0
        self.downtime = 0.0             # completed outages (heal-accounted)
        self._link_down_t = np.zeros((k, k), np.float64)
        self._gmn_down_t = np.zeros(k, np.float64)

    def _takeover(self, c: int) -> int:
        """The live GMN a dead cluster's management work re-homes to:
        ``min_search`` over alive total loads, lowest index on ties —
        the wall-clock mirror of ``core/sim._takeover``."""
        if self.gmn_alive[c]:
            return c
        alive = np.nonzero(self.gmn_alive)[0]
        if alive.size == 0:
            raise RuntimeError("every GMN is dead; heal one first")
        loads = np.array([self.schedulers[a].total_load() for a in alive])
        return int(alive[int(np.argmin(loads))])

    def submit(self, req: Request, via_cluster: Optional[int] = None):
        entry = via_cluster if via_cluster is not None \
            else next(self._counter) % self.k
        entry0 = entry
        entry = self._takeover(entry)       # dead entry GMN: hot-spare homes
        sched = self.schedulers[entry]
        target = sched.pick_cluster(self.t, req.rid)  # stage 1 (stale view ok)
        target0 = target
        target = self._takeover(target)     # dead pick: re-home at delivery
        if target != target0 or entry != entry0:
            self.reroutes += 1
        elif not self.link_up[entry, target] and entry != target:
            self.reroutes += 1              # task-start detoured, never lost
        tsched = self.schedulers[target]
        g = tsched.place_local(req)                 # stage 2 (exact)
        self.active.setdefault((target, g), []).append(req)
        self._broadcast(tsched)

    def _broadcast(self, sched: ClusterScheduler):
        if not self.gmn_alive[sched.cid]:
            return                          # dead managers don't beacon
        msg = sched.maybe_beacon(self.t)
        if msg is not None:
            self.beacons_tx += 1
            delays = T.host_beacon_delays(self.topology, self.k, sched.cid,
                                          c_b=self.msg_delay,
                                          c_hop=self.hop_delay)
            for s in self.schedulers:
                if s.cid == sched.cid:
                    continue
                # best-effort: a down (src, rcv) link or dead receiver
                # drops the delivery at injection time (DESIGN.md §13)
                if not self.link_up[sched.cid, s.cid] \
                        or not self.gmn_alive[s.cid]:
                    self.msgs_lost += 1
                    continue
                d = float(delays[s.cid])
                if d <= 0.0:
                    s.recv_beacon(msg, self.t)      # ideal: instant fan-out
                    self.beacons_rx += 1
                else:
                    heapq.heappush(self.pending, (self.t + d,
                                                  next(self._seq),
                                                  s.cid, msg))

    def _deliver_pending(self):
        """Deliver every in-flight beacon that has reached its receiver."""
        while self.pending and self.pending[0][0] <= self.t:
            at, _, rcv, msg = heapq.heappop(self.pending)
            self.schedulers[rcv].recv_beacon(msg, at)
            self.beacons_rx += 1

    def tick(self, dt: float = 1.0):
        """Advance decode: each group serves its batch at a shared rate."""
        self.t += dt
        self._deliver_pending()
        for key, reqs in list(self.active.items()):
            c, g = key
            sched = self.schedulers[c]
            if not sched.alive[g] or not reqs:
                if not reqs:
                    self.active.pop(key)
                continue
            rate = self.tokens_per_tick * dt / max(len(reqs), 1)
            still = []
            for r in reqs:
                r.done += rate
                if r.done >= r.max_new:
                    r.finished_at = self.t
                    sched.release(r)
                    self.finished.append(r)
                else:
                    still.append(r)
            if still:
                self.active[key] = still
            else:
                self.active.pop(key)
        # poll every scheduler once per tick, not just those with active
        # requests: a drained cluster's load drop (and the periodic/hybrid
        # T_b deadline) must still reach the remote views
        for sched in self.schedulers:
            self._broadcast(sched)

    def kill(self, cluster: int, group: int):
        """Fail a worker group: requeue its in-flight requests elsewhere."""
        sched = self.schedulers[cluster]
        sched.kill_group(group)
        orphans = self.active.pop((cluster, group), [])
        self._broadcast(sched)
        for r in orphans:
            r.cluster = r.group = -1
            self.submit(r)
        return len(orphans)

    # -- management-fabric faults (DESIGN.md §13) ---------------------------

    def fail_link(self, src: int, dst: int, *, symmetric: bool = True):
        """Take the directed beacon link src -> dst down (and dst -> src
        with ``symmetric``).  Idempotent; beacons injected while down are
        lost, task-start placements detour (``reroutes``)."""
        pairs = ((src, dst), (dst, src)) if symmetric else ((src, dst),)
        for i, j in pairs:
            if self.link_up[i, j]:
                self.link_up[i, j] = False
                self._link_down_t[i, j] = self.t

    def heal_link(self, src: int, dst: int, *, symmetric: bool = True):
        """Re-raise a failed link; the completed outage adds to
        ``downtime``.  Healing an up link is a no-op."""
        pairs = ((src, dst), (dst, src)) if symmetric else ((src, dst),)
        for i, j in pairs:
            if not self.link_up[i, j]:
                self.link_up[i, j] = True
                self.downtime += self.t - self._link_down_t[i, j]

    def fail_gmn(self, cluster: int):
        """Take a whole cluster's manager down: it stops beaconing, its
        pending (queued-but-unplaced) management work re-homes to the
        least-loaded live GMN, and placements that would land on it
        detour through :meth:`_takeover`.  Its worker groups keep
        decoding — a manager failure is a control-plane outage, not a
        data-plane one (matching ``core/sim``'s GMN_FAIL semantics)."""
        if not self.gmn_alive[cluster]:
            return 0
        if not self.gmn_alive.sum() > 1:
            raise RuntimeError("cannot fail the last live GMN")
        self.gmn_alive[cluster] = False
        self._gmn_down_t[cluster] = self.t
        rehomed = [r for r in self.queue if r.cluster == cluster]
        for r in rehomed:
            self.queue.remove(r)
            r.cluster = r.group = -1
            self.reroutes += 1
            self.submit(r)
        return len(rehomed)

    def heal_gmn(self, cluster: int):
        """Bring a failed manager back.  Its exact local table was never
        lost (workers kept running); the outage adds to ``downtime`` and
        the healed GMN re-enters beacon rotation on the next tick."""
        if self.gmn_alive[cluster]:
            return
        self.gmn_alive[cluster] = True
        self.downtime += self.t - self._gmn_down_t[cluster]

    def loads(self) -> np.ndarray:
        return np.stack([s.local for s in self.schedulers])

    def imbalance(self) -> float:
        l = self.loads()
        alive = np.stack([s.alive for s in self.schedulers])
        vals = l[alive]
        return float(vals.max() / max(vals.mean(), 1e-9)) if vals.size else 0.0
