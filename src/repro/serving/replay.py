"""Cross-validation of the wall-clock control plane against the
tick-domain simulator via trace replay (ROADMAP item; DESIGN.md §10).

``core/policies.py`` guarantees that the traced and host forms of every
policy agree on synthetic per-decision unit tests.  This module closes
the remaining gap: it replays *real* decision sequences recorded from a
TLM simulation through the serving engine's ``ClusterScheduler`` and
checks the wall-clock adapter reproduces every stage-1 choice the
tick-domain policy made — same views, same staleness ages, same
round-robin pointers, hundreds of decisions deep into a workload rather
than one decision in isolation.

Usage:

    p = SimParams(m=64, k=8, record_s1=True, mapping="staleness_weighted")
    st = sim.run(p, *workload, sim_len)
    trace = decision_trace(st, arrival_gmns)
    report = replay_decisions(trace, p)      # report.mismatches == []

``record_s1=True`` makes the simulator keep, per application, the
(possibly stale) view each stage-1 decision saw, the shared age vector,
the chosen clusters, and the pre-fork round-robin pointer (state leaves
``dec_view``/``dec_age``/``dec_choice``/``dec_rr0``/``dec_t``).

``replay_trace`` additionally drives a full :class:`FleetSim` from the
recorded arrival sequence — one request per application, submitted at
the recorded tick through the recorded entry cluster — as an end-to-end
exercise of the wall-clock engine on a TLM-shaped load.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.serving.engine import ClusterScheduler, FleetSim, Request


@dataclass
class Decision:
    """One recorded stage-1 decision: inputs and the tick-domain choice."""
    app: int
    i: int                       # decision index within the fork
    gmn: int                     # deciding GMN
    rr: int                      # round-robin pointer at decision time
    view: np.ndarray             # (k,) load summaries the decision saw
    age: np.ndarray              # (k,) staleness ages (own entry 0)
    t: float                     # arrival tick of the application
    chosen: int                  # cluster the tick-domain policy picked


@dataclass
class ReplayReport:
    n_decisions: int
    mismatches: list = field(default_factory=list)

    @property
    def agreement(self) -> float:
        if self.n_decisions == 0:
            return 1.0
        return 1.0 - len(self.mismatches) / self.n_decisions


def decision_trace(state, arrival_gmns) -> list[Decision]:
    """Extract the recorded stage-1 decisions from a ``record_s1=True``
    final state, in application order (completed ARRIVEs only).

    Under fault injection the deciding GMN can differ from the arrival
    GMN — a dead manager's work re-homes via the ``min_search`` takeover
    (DESIGN.md §13) — so fault-aware runs record the post-takeover
    decider in ``dec_gmn`` and the trace prefers it; no-fault states
    fall back to ``arrival_gmns`` unchanged."""
    if "dec_choice" not in state:
        raise ValueError("state has no decision trace; run the simulator "
                         "with record_s1=True (SimParams/SimShape)")
    arr = np.asarray(state["app_arrive"])
    views = np.asarray(state["dec_view"])
    ages = np.asarray(state["dec_age"])
    choices = np.asarray(state["dec_choice"])
    rr0 = np.asarray(state["dec_rr0"])
    ts = np.asarray(state["dec_t"])
    dec_gmn = state.get("dec_gmn")
    gmns = np.asarray(dec_gmn if dec_gmn is not None else arrival_gmns)
    ns = choices.shape[1]
    out = []
    for app in np.nonzero(arr < 1e17)[0]:
        for i in range(ns):
            out.append(Decision(
                app=int(app), i=i, gmn=int(gmns[app]),
                rr=int(rr0[app]) + i,
                view=views[app, i], age=ages[app],
                t=float(ts[app]), chosen=int(choices[app, i])))
    return out


def _forced_scheduler(dec: Decision, p) -> ClusterScheduler:
    """A ClusterScheduler whose observable state equals the recorded
    decision inputs: remote views/receipt times forced, own load set so
    ``total_load()`` reproduces the view's own entry."""
    k = dec.view.shape[0]
    s = ClusterScheduler(dec.gmn, k, n_groups=1, dn_th=p.dn_th,
                         mapping=p.mapping, T_b=p.T_b)
    s.remote = dec.view.astype(np.float64)
    s.remote_t = dec.t - dec.age.astype(np.float64)
    s.local[0] = float(dec.view[dec.gmn])        # own entry is exact
    s.map_ctr = dec.rr
    return s


def replay_decisions(trace, p) -> ReplayReport:
    """Replay every recorded stage-1 decision through the wall-clock
    ClusterScheduler and compare choices.

    ``p`` is the SimParams the trace was recorded under (its ``mapping``,
    ``dn_th``, ``T_b`` are used).  The hashed_random policy salts with
    (app, i), matching the tick domain's (app, decision-index) salt.
    """
    from repro.core import policies as P

    report = ReplayReport(n_decisions=len(trace))
    # two recorded configurations cannot round-trip through a live
    # ClusterScheduler and go through the shared host adapter directly:
    # hashed_random salts with the intra-fork decision index (pick_cluster
    # makes one decision per request, i is always 0), and
    # staleness_weighted with T_b=inf (the tick domain's degenerate
    # min_search form, which the scheduler constructor rejects)
    direct = p.mapping == "hashed_random" or (
        p.mapping == "staleness_weighted" and not np.isfinite(p.T_b))
    for dec in trace:
        if direct:
            got = P.host_pick(p.mapping, dec.view, dec.age, own=dec.gmn,
                              rr=dec.rr, salt=dec.app, i=dec.i, T_b=p.T_b)
        else:
            s = _forced_scheduler(dec, p)
            got = s.pick_cluster(now=dec.t, salt=dec.app)
        if got != dec.chosen:
            report.mismatches.append((dec, got))
    return report


def replay_trace(state, workload, p, *, wall_per_tick: float = 1e-3,
                 groups_per_cluster: int = 4,
                 max_new: int = 8) -> FleetSim:
    """Drive a FleetSim from a recorded TLM run: one request per
    completed application, submitted at ``arrival * wall_per_tick``
    through the recorded entry cluster, decoding between arrivals.

    Returns the driven FleetSim (callers assert on ``finished``,
    ``beacons_tx``, per-cluster loads, ...)."""
    arrivals, arrival_gmns, _ = workload
    arr = np.asarray(state["app_arrive"])
    order = [int(a) for a in np.argsort(np.asarray(arrivals))
             if arr[a] < 1e17]
    fleet = FleetSim(k=p.k, groups_per_cluster=groups_per_cluster,
                     dn_th=p.dn_th, mapping=p.mapping, beacon=p.beacon,
                     T_b=p.T_b if np.isfinite(p.T_b) else float("inf"))
    for app in order:
        t_wall = float(arrivals[app]) * wall_per_tick
        while fleet.t < t_wall:
            fleet.tick(min(1.0, t_wall - fleet.t))
        fleet.submit(Request(sort_key=t_wall, rid=app, max_new=max_new),
                     via_cluster=int(arrival_gmns[app]))
    for _ in range(10_000):
        if not fleet.active:
            break
        fleet.tick()
    return fleet
