"""Sharded checkpointing: async save, atomic commit, elastic restore.

Layout:  <dir>/step_<N>/
           meta.json                 {step, tree structure, shapes, dtypes}
           shard_<i>.npz             flat arrays owned by host i
           COMMIT                    written last — restore ignores
                                     directories without it (crash safety)

Restore re-shards to whatever mesh the new process uses (device_put with
the new shardings), so a 256-chip checkpoint restores onto 512 chips and
vice versa — the elastic-scaling path (tests/test_checkpoint.py).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(directory: str, step: int, tree, *, host: int = 0,
         async_: bool = False, keep: int = 3):
    """Write one checkpoint; returns the (eventual) path."""
    path = os.path.join(directory, f"step_{step:08d}")
    # snapshot SYNCHRONOUSLY: the caller's next step may donate these
    # buffers; only the file I/O happens on the background thread
    leaves, _ = _flatten(tree)
    arrs = [np.asarray(jax.device_get(x)) for x in leaves]

    def _write():
        os.makedirs(path, exist_ok=True)
        np.savez(os.path.join(path, f"shard_{host}.npz"),
                 **{f"a{i}": a for i, a in enumerate(arrs)})
        meta = {
            "step": step,
            "n_leaves": len(arrs),
            "treedef": jax.tree_util.tree_structure(tree).serialize_using_proto().hex(),
            "shapes": [list(a.shape) for a in arrs],
            "dtypes": [str(a.dtype) for a in arrs],
            "time": time.time(),
        }
        with open(os.path.join(path, "meta.json"), "w") as f:
            json.dump(meta, f)
        with open(os.path.join(path, "COMMIT"), "w") as f:
            f.write("ok")
        _gc(directory, keep)

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return path, t
    _write()
    return path, None


def _gc(directory: str, keep: int):
    steps = sorted(committed_steps(directory))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)


def committed_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for d in os.listdir(directory):
        if d.startswith("step_") and \
                os.path.exists(os.path.join(directory, d, "COMMIT")):
            out.append(int(d.split("_")[1]))
    return sorted(out)


def latest_step(directory: str):
    steps = committed_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, example_tree, *, step: int | None = None,
            host: int = 0, shardings=None):
    """Load a committed checkpoint; ``example_tree`` supplies the pytree
    structure (any tree with the right treedef, e.g. abstract params).
    ``shardings`` may target a different mesh than the one that saved it
    (elastic restore: device_put re-shards)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(path, f"shard_{host}.npz"))
    leaves = [data[f"a{i}"] for i in range(meta["n_leaves"])]
    treedef = jax.tree_util.tree_structure(example_tree)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return meta["step"], tree
