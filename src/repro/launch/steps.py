"""Step builders: train / prefill / decode, with shardings + input specs.

`build_cell(cfg, shape, mesh)` returns everything the dry-run, trainer and
server need for one (architecture x input-shape x mesh) cell:
the jit-able step function, ShapeDtypeStruct input stand-ins, and
in/out shardings.  No device allocation happens here.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.models import model as MDL
from repro.optim import optimizer as OPT
from repro.parallel import sharding as SH
from repro.parallel.ctx import cell_rules, sharding_rules


# --------------------------------------------------------------------------
# Abstract inputs (ShapeDtypeStruct stand-ins; never allocated)
# --------------------------------------------------------------------------

def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Model inputs for one cell (tokens/labels or decode token+cache extras)."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        spec = {"tokens": sds((B, _text_len(cfg, S)), jnp.int32),
                "labels": sds((B, _text_len(cfg, S)), jnp.int32)}
        spec.update(_frontend_specs(cfg, B))
        return spec
    if shape.kind == "prefill":
        spec = {"tokens": sds((B, _text_len(cfg, S)), jnp.int32)}
        spec.update(_frontend_specs(cfg, B))
        return spec
    # decode: one new token against a cache of S
    return {"token": sds((B, 1), jnp.int32), "pos": sds((), jnp.int32)}


def _text_len(cfg: ModelConfig, S: int) -> int:
    return S - cfg.vision_tokens if cfg.frontend == "vision" else S


def _frontend_specs(cfg: ModelConfig, B: int) -> dict:
    if cfg.frontend == "vision":
        return {"patches": sds((B, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)}
    if cfg.family == "encdec":
        return {"frames": sds((B, cfg.enc_seq_len, cfg.d_model), jnp.bfloat16)}
    return {}


def abstract_params(cfg: ModelConfig, dtype=jnp.bfloat16):
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(
        functools.partial(MDL.init_model, cfg=cfg, dtype=dtype), key)


def abstract_opt_state(params_shape, run: RunConfig):
    return jax.eval_shape(
        functools.partial(OPT.init_opt_state, run=run), params_shape)


def abstract_cache(cfg: ModelConfig, shape: ShapeConfig, dtype=jnp.bfloat16):
    B, S = shape.global_batch, shape.seq_len

    def build(params):
        enc_out = None
        if cfg.family == "encdec":
            enc_out = jnp.zeros((B, cfg.enc_seq_len, cfg.d_model), dtype)
        return MDL.init_cache(cfg, B, S, dtype, enc_out=enc_out,
                              params=params)

    return jax.eval_shape(build, abstract_params(cfg, dtype))


# --------------------------------------------------------------------------
# Step functions
# --------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, run: RunConfig) -> Callable:
    def train_step(params, opt, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        extra = {k: v for k, v in batch.items() if k not in ("tokens", "labels")}

        def loss_fn(p, tok, lab, ext):
            return MDL.lm_loss(p, cfg, tok, lab, extra=ext, remat=run.remat)

        if run.microbatches > 1:
            n = run.microbatches
            Bm = tokens.shape[0] // n

            def micro(carry, i):
                acc, metrics_acc = carry
                sl = lambda a: jax.lax.dynamic_slice_in_dim(a, i * Bm, Bm)  # noqa: E731
                ext = {k: sl(v) for k, v in extra.items()}
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, sl(tokens), sl(labels), ext)
                acc = jax.tree_util.tree_map(jnp.add, acc, g)
                return (acc, jax.tree_util.tree_map(jnp.add, metrics_acc,
                                                    {"loss": l, **m})), None

            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            zero_m = {"loss": 0.0, "nll": 0.0, "load_balance": 0.0,
                      "dropped_frac": 0.0}
            zero_m = jax.tree_util.tree_map(jnp.float32, zero_m)
            (grads, metrics), _ = jax.lax.scan(
                micro, (zero_g, zero_m), jnp.arange(n))
            grads = jax.tree_util.tree_map(lambda g: g / n, grads)
            metrics = jax.tree_util.tree_map(lambda m: m / n, metrics)
            loss = metrics.pop("loss")
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, tokens, labels, extra)

        params, opt, opt_metrics = OPT.adamw_update(params, grads, opt, run)
        return params, opt, {"loss": loss, **metrics, **opt_metrics}

    if run.grad_compression == "int8":
        from repro.parallel import compression as COMP
        base = train_step

        def train_step_compressed(params, opt, err, batch):
            # recompute grads, compress w/ error feedback, then update —
            # reuses the uncompressed path via a grad hook
            tokens, labels = batch["tokens"], batch["labels"]
            extra = {k: v for k, v in batch.items()
                     if k not in ("tokens", "labels")}
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: MDL.lm_loss(p, cfg, tokens, labels, extra=extra,
                                      remat=run.remat), has_aux=True)(params)
            grads, err = COMP.compress_grads(grads, err)
            params, opt, opt_metrics = OPT.adamw_update(params, grads, opt, run)
            return params, opt, err, {"loss": loss, **metrics, **opt_metrics}

        return train_step_compressed

    return train_step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    def prefill_step(params, batch):
        tokens = batch["tokens"]
        extra = {k: v for k, v in batch.items() if k != "tokens"}
        hidden, _ = MDL.forward(params, cfg, tokens, extra=extra, remat="none",
                                return_hidden=True)
        from repro.models import layers as L
        return L.unembed(params["embed"], hidden[:, -1:])[:, 0]
        # next-token logits only; full (B,S,V) logits are never materialized

    return prefill_step


def make_decode_step(cfg: ModelConfig) -> Callable:
    def decode_step(params, cache, token, pos):
        return MDL.decode_step(params, cfg, cache, token, pos)

    return decode_step


# --------------------------------------------------------------------------
# Cell assembly (step + specs + shardings)
# --------------------------------------------------------------------------

@dataclass
class Cell:
    name: str
    step: Callable
    args: tuple                  # abstract args (ShapeDtypeStructs)
    in_shardings: tuple
    out_shardings: Any
    donate: tuple


def _batch_shardings(mesh, specs: dict, multi_pod: bool):
    dp = ("pod", "data") if multi_pod and "pod" in mesh.axis_names else ("data",)
    dp_entry = dp if len(dp) > 1 else dp[0]
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    out = {}
    for k, v in specs.items():
        if v.ndim == 0 or v.shape[0] % dp_size != 0:
            out[k] = NamedSharding(mesh, P())
        else:
            out[k] = NamedSharding(mesh, P(*([dp_entry] + [None] * (v.ndim - 1))))
    return out


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, run: RunConfig,
               *, multi_pod: bool = False) -> Cell:
    specs = input_specs(cfg, shape)
    params = abstract_params(cfg, jnp.dtype(run.param_dtype))
    tp = run.layout != "zero3"  # "sp" keeps TP params, seq-shards activations
    pshard = SH.param_shardings(
        cfg, mesh, params, tp=tp,
        fsdp=shape.kind == "train" or _needs_fsdp(cfg) or not tp)
    rules = cell_rules(cfg, mesh, batch=shape.global_batch,
                       multi_pod=multi_pod, layout=run.layout)
    rep = NamedSharding(mesh, P())

    if shape.kind == "train":
        opt = abstract_opt_state(params, run)
        oshard = OPT.OptState(
            step=rep,
            mu=SH.param_shardings(cfg, mesh, opt.mu, fsdp=True, tp=tp),
            nu=SH.param_shardings(cfg, mesh, opt.nu, fsdp=True, tp=tp))
        bshard = _batch_shardings(mesh, specs, multi_pod)
        raw = make_train_step(cfg, run)

        def step(params, opt, batch):
            with sharding_rules(mesh, rules):
                return raw(params, opt, batch)

        return Cell(
            name=f"{cfg.name}/{shape.name}",
            step=step,
            args=(params, opt, specs),
            in_shardings=(pshard, oshard, bshard),
            out_shardings=(pshard, oshard, rep),
            donate=(0, 1))

    if shape.kind == "prefill":
        bshard = _batch_shardings(mesh, specs, multi_pod)
        raw = make_prefill_step(cfg)

        def step(params, batch):
            with sharding_rules(mesh, rules):
                return raw(params, batch)

        out_sh = NamedSharding(mesh, P("data", "model"))
        return Cell(
            name=f"{cfg.name}/{shape.name}",
            step=step,
            args=(params, specs),
            in_shardings=(pshard, bshard),
            out_shardings=out_sh,
            donate=())

    # decode
    cache = abstract_cache(cfg, shape, jnp.dtype(run.param_dtype))
    cshard = SH.cache_shardings(cfg, mesh, cache, shape.global_batch)
    bshard = _batch_shardings(mesh, specs, multi_pod=False)
    raw = make_decode_step(cfg)

    def step(params, cache, token, pos):
        with sharding_rules(mesh, rules):
            return raw(params, cache, token, pos)

    logits_sh = NamedSharding(
        mesh, P("data" if shape.global_batch % mesh.shape["data"] == 0
                else None, None, "model"))
    return Cell(
        name=f"{cfg.name}/{shape.name}",
        step=step,
        args=(params, cache, specs["token"], specs["pos"]),
        in_shardings=(pshard, cshard, bshard["token"], rep),
        out_shardings=(logits_sh, cshard),
        donate=(1,))


def _needs_fsdp(cfg: ModelConfig) -> bool:
    # >= ~20B params cannot hold bf16 replica per TP group member on v5e
    return cfg.param_count() * 2 / 16 > 8e9


def lower_cell(cell: Cell):
    fn = jax.jit(cell.step,
                 in_shardings=cell.in_shardings,
                 out_shardings=cell.out_shardings,
                 donate_argnums=cell.donate)
    return fn.lower(*cell.args)
