"""Trip-count-aware HLO cost analysis (text parser).

XLA's built-in ``HloCostAnalysis`` (exposed as ``compiled.cost_analysis()``)
visits each while-loop body ONCE — for scan-over-layers models that
under-counts FLOPs/bytes/collectives by the trip count (80x for qwen2-72b!).
This module re-derives the three roofline inputs from ``compiled.as_text()``:

  flops       — dot ops (2 * output_elems * contraction_elems), recursing
                into fusion computations, multiplying while bodies by their
                trip counts (parsed from the loop condition constant).
  hbm bytes   — boundary traffic: for fusions, parameters + outputs only
                (internals stay in registers/VMEM — closer to real HBM
                traffic than per-op accounting); for top-level ops,
                operands + outputs.
  collectives — per-kind byte counts with ring-algorithm weights,
                times the enclosing loops' trip counts.

Validated against analytic 6ND/8ND estimates in tests/test_hlo_cost.py.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\](?:\{[\d,]*\})?")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\(")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_COLL_WEIGHT = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}

_ZERO_COST_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
                  "bitcast", "after-all", "partition-id", "replica-id"}


def _shape_elems_bytes(type_str: str):
    """-> (elems, bytes) summed over all array shapes in the type string."""
    elems = 0
    nbytes = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


def _tpu_bytes(type_str: str) -> float:
    """bf16-equivalent bytes: the CPU backend promotes bf16 dot operands /
    collectives to f32; a TPU build keeps them bf16.  Large f32 arrays are
    therefore counted at 2 B/elem for the 'tpu-corrected' terms."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        b = _DTYPE_BYTES[dt]
        if dt == "f32" and n >= 262_144:     # >=1MB f32 arrays
            b = 2
        total += n * b
    return total


@dataclass
class Op:
    name: str
    kind: str
    type_str: str
    line: str
    operands: list = field(default_factory=list)


@dataclass
class Computation:
    name: str
    ops: dict = field(default_factory=dict)   # name -> Op
    order: list = field(default_factory=list)


def parse_module(hlo_text: str) -> dict:
    comps: dict[str, Computation] = {}
    cur = None
    for raw in hlo_text.splitlines():
        line = raw.strip()
        if not line:
            continue
        # computation header: "%name (params...) -> type {" — params may have
        # nested parens (tuple types) and /*index=N*/ comments, so detect as
        # a brace-terminated arrow line that is NOT an op definition.
        if (line.rstrip().endswith("{") and "->" in line
                and not re.match(r"^\s*(?:ROOT\s+)?%[\w.\-]+\s*=", line)):
            hdr = line[5:] if line.startswith("ENTRY") else line
            name = hdr.strip().lstrip("%").split(" ")[0].split("(")[0]
            cur = Computation(name)
            comps[name] = cur
            if line.startswith("ENTRY"):
                comps["__entry__"] = cur
            continue
        if cur is None:
            continue
        m = _DEF_RE.match(line)
        if m:
            name, type_str, kind = m.groups()
            args = line.split(f"{kind}(", 1)[1] if f"{kind}(" in line else ""
            operands = _OPERAND_RE.findall(args.split(")", 1)[0])
            cur.ops[name] = Op(name, kind, type_str, line, operands)
            cur.order.append(name)
    return comps


def _dot_flops(op: Op, comp: Computation, comps: dict) -> float:
    out_elems, _ = _shape_elems_bytes(op.type_str)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    if not m or not op.operands:
        return 2.0 * out_elems  # unknown contraction: lower bound
    lhs_name = op.operands[0]
    lhs_shape = None
    if lhs_name in comp.ops:
        lhs_shape = comp.ops[lhs_name].type_str
    if lhs_shape is None:
        return 2.0 * out_elems
    dims_match = _SHAPE_RE.search(lhs_shape)
    if not dims_match:
        return 2.0 * out_elems
    dims = [int(d) for d in dims_match.group(2).split(",") if d]
    contract = 1
    for idx in m.group(1).split(","):
        if idx and int(idx) < len(dims):
            contract *= dims[int(idx)]
    return 2.0 * out_elems * contract


def _trip_count(cond: Computation) -> int:
    """Trip count from the loop condition's comparison constant."""
    best = 1
    for op in cond.ops.values():
        if op.kind == "constant":
            m = re.search(r"constant\((-?\d+)\)", op.line)
            if m:
                best = max(best, int(m.group(1)))
    return best


@dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    hbm_bytes_tpu: float = 0.0
    coll_bytes: dict = field(default_factory=dict)      # kind -> bytes
    coll_weighted: float = 0.0
    coll_weighted_tpu: float = 0.0

    def add(self, other: "Cost", times: float = 1.0):
        self.flops += other.flops * times
        self.hbm_bytes += other.hbm_bytes * times
        self.hbm_bytes_tpu += other.hbm_bytes_tpu * times
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + v * times
        self.coll_weighted += other.coll_weighted * times
        self.coll_weighted_tpu += other.coll_weighted_tpu * times


def _operand_bytes(op: Op, comp: Computation) -> float:
    total = 0.0
    for name in op.operands:
        o = comp.ops.get(name)
        if o is not None and o.kind != "constant":
            _, b = _shape_elems_bytes(o.type_str)
            total += b
    return total


def _operand_bytes_tpu(op: Op, comp: Computation) -> float:
    total = 0.0
    for name in op.operands:
        o = comp.ops.get(name)
        if o is not None and o.kind != "constant":
            total += _tpu_bytes(o.type_str)
    return total


def _comp_cost(comp: Computation, comps: dict, memo: dict) -> Cost:
    if comp.name in memo:
        return memo[comp.name]
    cost = Cost()
    memo[comp.name] = cost                  # guards (benign) recursion
    for name in comp.order:
        op = comp.ops[name]
        kind = op.kind
        if kind in _ZERO_COST_OPS:
            continue
        _, out_bytes = _shape_elems_bytes(op.type_str)
        out_bytes_tpu = _tpu_bytes(op.type_str)
        if kind == "fusion":
            m = _CALLS_RE.search(op.line)
            if m and m.group(1) in comps:
                inner = _comp_cost(comps[m.group(1)], comps, memo)
                cost.flops += inner.flops
                for k, v in inner.coll_bytes.items():
                    cost.coll_bytes[k] = cost.coll_bytes.get(k, 0.0) + v
                cost.coll_weighted += inner.coll_weighted
                cost.coll_weighted_tpu += inner.coll_weighted_tpu
            # HBM traffic at fusion boundary only
            cost.hbm_bytes += _operand_bytes(op, comp) + out_bytes
            cost.hbm_bytes_tpu += _operand_bytes_tpu(op, comp) + out_bytes_tpu
        elif kind == "while":
            body = _BODY_RE.search(op.line)
            cond = _COND_RE.search(op.line)
            m = re.search(r'known_trip_count[^}]*?"n":"(\d+)"', op.line)
            if m:
                trips = int(m.group(1))
            elif cond and cond.group(1) in comps:
                trips = _trip_count(comps[cond.group(1)])
            else:
                trips = 1
            if body and body.group(1) in comps:
                cost.add(_comp_cost(comps[body.group(1)], comps, memo),
                         times=max(trips, 1))
        elif kind in ("call", "custom-call", "conditional", "async-start"):
            m = _CALLS_RE.search(op.line)
            if m and m.group(1) in comps:
                cost.add(_comp_cost(comps[m.group(1)], comps, memo))
            cost.hbm_bytes += _operand_bytes(op, comp) + out_bytes
            cost.hbm_bytes_tpu += _operand_bytes_tpu(op, comp) + out_bytes_tpu
        elif kind.startswith(COLLECTIVES):
            base = next(c for c in COLLECTIVES if kind.startswith(c))
            b = out_bytes if base != "reduce-scatter" \
                else _operand_bytes(op, comp)
            b_tpu = _tpu_bytes(op.type_str) if base != "reduce-scatter" \
                else _operand_bytes_tpu(op, comp)
            cost.coll_bytes[base] = cost.coll_bytes.get(base, 0.0) + b
            cost.coll_weighted += _COLL_WEIGHT[base] * b
            cost.coll_weighted_tpu += _COLL_WEIGHT[base] * b_tpu
            cost.hbm_bytes += _operand_bytes(op, comp) + out_bytes
            cost.hbm_bytes_tpu += _operand_bytes_tpu(op, comp) + out_bytes_tpu
        elif kind in ("dot", "convolution"):
            cost.flops += _dot_flops(op, comp, comps)
            cost.hbm_bytes += _operand_bytes(op, comp) + out_bytes
            cost.hbm_bytes_tpu += _operand_bytes_tpu(op, comp) + out_bytes_tpu
        else:
            # elementwise / reduce / copy / dynamic-slice etc.
            cost.hbm_bytes += _operand_bytes(op, comp) + out_bytes
            cost.hbm_bytes_tpu += _operand_bytes_tpu(op, comp) + out_bytes_tpu
    memo[comp.name] = cost
    return cost


def analyze_hlo(hlo_text: str) -> Cost:
    comps = parse_module(hlo_text)
    entry = comps.get("__entry__")
    if entry is None:  # fall back: biggest computation
        entry = max(comps.values(), key=lambda c: len(c.order))
    return _comp_cost(entry, comps, {})
