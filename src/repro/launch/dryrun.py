import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "")
    # 512 placeholder host devices for the production meshes (dry-run only).
    + " --xla_force_host_platform_device_count=512"
    # CPU-backend artifact: WLICM hoists bf16->f32 converts of remat-saved
    # scan residuals out of the backward while, materializing a duplicate
    # f32 residual stack (+10GB/chip on qwen2-72b).  The TPU backend keeps
    # native bf16 dots and never creates these converts.  See §Perf.
    + " --xla_disable_hlo_passes=while-loop-invariant-code-motion").strip()

# Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.
#
# Proves the distribution config is coherent without hardware: the 16x16
# single-pod mesh and the 2x16x16 multi-pod mesh must compile for every
# supported cell; memory_analysis() proves HBM fit; cost_analysis() + the HLO
# collective parse feed EXPERIMENTS.md §Dry-run / §Roofline.
#
# Usage:
#   python -m repro.launch.dryrun --arch qwen2_72b --shape train_4k --mesh single
#   python -m repro.launch.dryrun --all --out results/dryrun.json
# (XLA_FLAGS is set on the first two lines, before any jax import.)

import argparse
import json
import time
import traceback

import jax

from repro.configs.base import (RunConfig, SHAPES, all_configs,
                                shape_supported)
from repro.launch import roofline as RL
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell, lower_cell


def run_cell(cfg, shape, *, multi_pod: bool, run: RunConfig,
             verbose: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    t0 = time.time()
    # trainer auto-picks gradient-accumulation depth to fit 16GB HBM
    mb_candidates = (run.microbatches, run.microbatches * 2,
                     run.microbatches * 4, run.microbatches * 8) \
        if shape.kind == "train" else (1,)
    info = None
    for mb in mb_candidates:
        import dataclasses
        run_mb = dataclasses.replace(run, microbatches=mb) \
            if shape.kind == "train" else run
        cell = build_cell(cfg, shape, mesh, run_mb, multi_pod=multi_pod)
        with mesh:
            lowered = lower_cell(cell)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            info = RL.analyze(compiled, cfg, shape, n_chips)
        info["microbatches"] = mb
        if info["fits_16gb"]:
            break
        jax.clear_caches()
    info.update({
        "cell": cell.name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "status": "ok",
    })
    if verbose:
        print(f"[dryrun] {cell.name} mesh={info['mesh']}: "
              f"compute={info['t_compute_s']*1e3:.2f}ms "
              f"memory={info['t_memory_s']*1e3:.2f}ms "
              f"collective={info['t_collective_s']*1e3:.2f}ms "
              f"bottleneck={info['bottleneck']} "
              f"peak={info['peak_bytes_per_chip']/1e9:.2f}GB "
              f"fits16GB={info['fits_16gb']} "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)", flush=True)
    return info


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--remat", default="full")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--layout", default="tp_fsdp",
                    choices=["tp_fsdp", "zero3", "sp"])
    args = ap.parse_args()

    run = RunConfig(remat=args.remat, microbatches=args.microbatches,
                    layout=args.layout)
    cfgs = all_configs()
    archs = [args.arch] if args.arch else list(cfgs)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    results = []
    failures = 0
    for arch in archs:
        cfg = cfgs[arch.replace("-", "_")]
        for shp in shapes:
            shape = SHAPES[shp]
            if not shape_supported(cfg, shape):
                results.append({"cell": f"{cfg.name}/{shape.name}",
                                "status": "skipped",
                                "reason": "full attention cannot serve 500k ctx"})
                print(f"[dryrun] {cfg.name}/{shape.name}: SKIP (unsupported)",
                      flush=True)
                continue
            for mp in meshes:
                try:
                    results.append(run_cell(cfg, shape, multi_pod=mp, run=run))
                except Exception as e:  # noqa: BLE001 — report, keep going
                    failures += 1
                    traceback.print_exc()
                    results.append({
                        "cell": f"{cfg.name}/{shape.name}",
                        "mesh": "2x16x16" if mp else "16x16",
                        "status": "fail", "error": f"{type(e).__name__}: {e}"})
                if args.out:
                    with open(args.out, "w") as f:
                        json.dump(results, f, indent=1)
                jax.clear_caches()
    ok = sum(1 for r in results if r.get("status") == "ok")
    print(f"[dryrun] done: {ok} ok, {failures} failed, "
          f"{sum(1 for r in results if r.get('status') == 'skipped')} skipped",
          flush=True)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
