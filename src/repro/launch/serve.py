"""Serving driver: clustered scheduler (control plane) + real decode steps
(data plane) on this host's devices.

  PYTHONPATH=src python -m repro.launch.serve --arch olmo_1b --reduced \\
      --requests 64 --clusters 4

The control plane is the paper's mechanism (two-stage placement + threshold
beacons, serving/engine.py); the data plane batches each group's active
requests through real jitted decode steps of the (reduced) model.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced_config
from repro.models import model as MDL
from repro.serving.engine import FleetSim, Request


def serve(cfg, *, n_requests: int = 64, clusters: int = 4,
          groups_per_cluster: int = 2, dn_th: int = 4, max_new: int = 8,
          decode_batch: int = 4, seed: int = 0, verbose=print):
    key = jax.random.PRNGKey(seed)
    params = MDL.init_model(key, cfg, jnp.float32)
    decode = jax.jit(lambda p, c, t, pos: MDL.decode_step(p, cfg, c, t, pos))

    fleet = FleetSim(k=clusters, groups_per_cluster=groups_per_cluster,
                     dn_th=dn_th)
    rng = np.random.default_rng(seed)
    reqs = [Request(sort_key=float(i), rid=i,
                    prompt_len=int(rng.integers(16, 128)),
                    max_new=max_new, arrived=float(i))
            for i in range(n_requests)]
    for r in reqs:
        fleet.submit(r)
    imbalance_at_submit = fleet.imbalance()

    # data plane: run one real decode wave per (cluster, group) batch
    t0 = time.time()
    waves = 0
    cache = MDL.init_cache(cfg, decode_batch, 64, jnp.float32)
    tok = jnp.zeros((decode_batch, 1), jnp.int32)
    while fleet.active and waves < max_new + 2:
        for key_ in list(fleet.active):
            batch = fleet.active[key_]
            if not batch:
                fleet.active.pop(key_)
                continue
            logits, cache = decode(params, cache, tok,
                                   jnp.int32(min(waves, 62)))
            tok = logits[:, -1:].argmax(-1).astype(jnp.int32)
        fleet.tick(dt=float(max_new))   # control plane: rate-based progress
        waves += 1
    dt = time.time() - t0

    done = len(fleet.finished)
    verbose(f"[serve] {done}/{n_requests} finished in {waves} waves "
            f"({dt:.1f}s); submit imbalance={imbalance_at_submit:.2f}; "
            f"beacons={fleet.beacons_tx}")
    return {"finished": done, "waves": waves,
            "imbalance": imbalance_at_submit,
            "beacons_tx": fleet.beacons_tx}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo_1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--clusters", type=int, default=4)
    ap.add_argument("--dn-th", type=int, default=4)
    args = ap.parse_args()
    cfg = reduced_config(get_config(args.arch))
    serve(cfg, n_requests=args.requests, clusters=args.clusters,
          dn_th=args.dn_th)


if __name__ == "__main__":
    main()
