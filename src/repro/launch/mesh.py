"""Production meshes.  Functions, not module constants — importing this file
never touches jax device state (the dry-run sets XLA_FLAGS first).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host has (CPU smoke tests: 1 device)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def dp_axes(mesh) -> tuple[str, ...]:
    names = mesh.axis_names
    return ("pod", "data") if "pod" in names else ("data",)
