"""Roofline model: compute / memory / collective terms from a compiled cell.

Target hardware: TPU v5e —
  peak bf16 compute : 197 TFLOP/s per chip
  HBM bandwidth     : 819 GB/s per chip
  ICI               : ~50 GB/s per link; we model an effective per-chip
                      collective bandwidth of 2 links (bidirectional ring)
                      = 100 GB/s and document the assumption here.

The compiled module is the *per-device* SPMD program, so `cost_analysis()`
FLOPs/bytes and the collective shapes parsed from `compiled.as_text()` are
per-chip quantities; terms below are therefore per-chip seconds directly
(equivalent to the global/chips formulation).

Collective time weights (ring algorithms, n participants, (n-1)/n ~ 1):
  all-gather        : out_bytes
  reduce-scatter    : in_bytes  (= sum of operand bytes)
  all-reduce        : 2 x out_bytes (RS + AG phases)
  all-to-all        : out_bytes
  collective-permute: out_bytes
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 100e9               # effective collective B/s per chip (2 x 50GB/s)

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s+(.*?)\s+(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: dict = field(default_factory=dict)
    count_by_kind: dict = field(default_factory=dict)
    weighted_bytes: float = 0.0


_WEIGHT = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
           "all-to-all": 1.0, "collective-permute": 1.0}


def parse_collectives(hlo_text: str) -> CollectiveStats:
    st = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        type_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(type_str)
        st.bytes_by_kind[kind] = st.bytes_by_kind.get(kind, 0) + b
        st.count_by_kind[kind] = st.count_by_kind.get(kind, 0) + 1
        st.weighted_bytes += _WEIGHT[kind] * b
    return st


def model_flops(cfg, shape) -> float:
    """Useful model FLOPs for the cell (6ND train / 2ND inference)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch      # one token per row


def analyze(compiled, cfg, shape, n_chips: int) -> dict:
    from repro.launch import hlo_cost

    xla_cost = compiled.cost_analysis()
    if isinstance(xla_cost, list):      # older jax returns [dict]
        xla_cost = xla_cost[0]
    text = compiled.as_text()
    # trip-count-aware accounting (XLA's HloCostAnalysis counts while bodies
    # once — 80x under-count for scan-over-layers; see hlo_cost.py)
    tc = hlo_cost.analyze_hlo(text)
    flops = tc.flops
    bytes_accessed = tc.hbm_bytes
    coll = parse_collectives(text)
    coll.weighted_bytes = tc.coll_weighted
    coll.bytes_by_kind = {k: int(v) for k, v in tc.coll_bytes.items()}

    mem = compiled.memory_analysis()
    mem_info = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "output_bytes": getattr(mem, "output_size_in_bytes", 0),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
    }
    # peak live ~ args + temps (aliased args overlap outputs)
    peak_bytes = mem_info["argument_bytes"] + mem_info["temp_bytes"]

    t_compute = flops / PEAK_FLOPS
    # 'tpu' variants: large f32 arrays counted at 2B/elem — the CPU backend
    # promotes bf16 dots/collectives to f32, a TPU build keeps native bf16.
    t_memory = tc.hbm_bytes_tpu / HBM_BW
    t_coll = tc.coll_weighted_tpu / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    mflops = model_flops(cfg, shape)
    return {
        "flops_per_chip": flops,
        "bytes_per_chip": bytes_accessed,
        "xla_flops_per_chip": float(xla_cost.get("flops", 0.0)),
        "xla_bytes_per_chip": float(xla_cost.get("bytes accessed", 0.0)),
        "collective_bytes_per_chip": coll.weighted_bytes,
        "collective_detail": {k: {"bytes": coll.bytes_by_kind[k],
                                  "count": coll.count_by_kind.get(k, 0)}
                              for k in coll.bytes_by_kind},
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "t_memory_raw_s": bytes_accessed / HBM_BW,
        "t_collective_raw_s": coll.weighted_bytes / ICI_BW,
        "bottleneck": bottleneck,
        "step_time_s": max(terms.values()),
        "model_flops_global": mflops,
        "model_flops_per_chip": mflops / n_chips,
        "useful_flops_ratio": (mflops / n_chips) / flops if flops else 0.0,
        "roofline_fraction": (mflops / n_chips / PEAK_FLOPS)
                             / max(terms.values()) if max(terms.values()) else 0.0,
        "memory": mem_info,
        "peak_bytes_per_chip": peak_bytes,
        "fits_16gb": peak_bytes < 16e9,
    }
