"""Fault-tolerant training driver.

Runs on whatever devices the host has (CPU smoke / single pod / the full
production mesh): checkpoint every N steps (async, atomic-commit), resume
from the latest committed step, deterministic data makes restarts and
straggler takeover stateless (data/pipeline.py), optional int8 gradient
compression with error feedback.

  PYTHONPATH=src python -m repro.launch.train --arch olmo_1b --steps 200 \\
      --reduced --ckpt-dir /tmp/ckpt [--resume] [--fail-at 120]

``--fail-at`` injects a crash at that step (exercises the restart path —
see tests/test_train_loop.py and examples/train_tiny_lm.py).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.ckpt import checkpoint as CKPT
from repro.configs.base import RunConfig, get_config, reduced_config
from repro.data.pipeline import DataConfig, DataIterator
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step
from repro.models import model as MDL
from repro.optim import optimizer as OPT
from repro.parallel import compression as COMP
from repro.parallel.ctx import activation_rules, sharding_rules


def train(cfg, run: RunConfig, *, steps: int, batch: int, seq: int,
          ckpt_dir=None, ckpt_every: int = 50, resume: bool = False,
          fail_at: int = -1, log_every: int = 10, verbose=print):
    key = jax.random.PRNGKey(run.seed)
    params = MDL.init_model(key, cfg, jnp.dtype(run.param_dtype))
    opt = OPT.init_opt_state(params, run)
    err = COMP.init_error_state(params) \
        if run.grad_compression == "int8" else None

    start = 0
    if resume and ckpt_dir and CKPT.latest_step(ckpt_dir) is not None:
        start, (params, opt_mu, opt_nu, step_arr) = CKPT.restore(
            ckpt_dir, (params, opt.mu, opt.nu, opt.step))
        opt = OPT.OptState(step=step_arr, mu=opt_mu, nu=opt_nu)
        verbose(f"[train] resumed from step {start}")

    data = DataIterator(cfg, batch, seq, DataConfig(seed=run.seed),
                        start_step=start)
    raw_step = make_train_step(cfg, run)
    compressed = run.grad_compression == "int8"
    step_fn = jax.jit(raw_step,
                      donate_argnums=(0, 1, 2) if compressed else (0, 1))

    mesh = make_host_mesh()
    losses = []
    pending = None
    t0 = time.time()
    with mesh, sharding_rules(mesh, activation_rules()):
        for s in range(start, steps):
            if s == fail_at:
                data.close()
                raise RuntimeError(f"injected failure at step {s}")
            b = next(data)
            if compressed:
                params, opt, err, metrics = step_fn(params, opt, err, b)
            else:
                params, opt, metrics = step_fn(params, opt, b)
            if (s + 1) % log_every == 0 or s + 1 == steps:
                loss = float(metrics["loss"])
                losses.append((s + 1, loss))
                verbose(f"[train] step {s+1}/{steps} loss={loss:.4f} "
                        f"lr={float(metrics['lr']):.2e} "
                        f"gnorm={float(metrics['grad_norm']):.2f} "
                        f"({(time.time()-t0):.1f}s)")
            if ckpt_dir and (s + 1) % ckpt_every == 0:
                if pending is not None:
                    pending.join()
                _, pending = CKPT.save(
                    ckpt_dir, s + 1,
                    (params, opt.mu, opt.nu, opt.step), async_=True)
    if pending is not None:
        pending.join()
    data.close()
    return params, opt, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo_1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at", type=int, default=-1)
    ap.add_argument("--schedule", default="cosine")
    ap.add_argument("--compression", default="none")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    run = RunConfig(schedule=args.schedule, total_steps=args.steps,
                    warmup_steps=max(args.steps // 20, 1),
                    learning_rate=args.lr, param_dtype="float32",
                    grad_compression=args.compression)
    train(cfg, run, steps=args.steps, batch=args.batch, seq=args.seq,
          ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
          resume=args.resume, fail_at=args.fail_at)


if __name__ == "__main__":
    main()
