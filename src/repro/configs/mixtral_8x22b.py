"""mixtral-8x22b — sparse MoE with sliding-window attention [arXiv:2401.04088].

56 layers, d_model 6144, 48 heads (GQA kv=8), 8 experts top-2 (d_ff 16384),
vocab 32768.  Sliding-window attention (W=4096) bounds the KV cache, so
``long_500k`` decode RUNS with a windowed cache.
"""
from repro.configs.base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="mixtral_8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_head=128,
    d_ff=16384,
    vocab_size=32768,
    norm="rms",
    sliding_window=4096,
    moe=MoEConfig(n_experts=8, top_k=2, n_shared=0, d_ff_expert=16384),
    supports_long_context=True,
    notes="SWA per assignment spec; long_500k uses windowed KV ring cache",
))
