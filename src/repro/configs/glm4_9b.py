"""glm4-9b — dense GQA transformer [hf:THUDM/glm-4-9b].

40 layers, d_model 4096, 32 heads (GQA kv=2), d_ff 13696, vocab 151552,
RoPE, QKV bias.  kv=2 is extreme KV sharing: the KV projections are
replicated across TP (2 not divisible by 16) while Q/FFN shard.
Full attention -> ``long_500k`` skipped.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="glm4_9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_head=128,
    d_ff=13696,
    vocab_size=151552,
    norm="rms",
    qkv_bias=True,
    supports_long_context=False,
    notes="GLM4 partial-rotary (50%) approximated as full RoPE; documented",
))
