from repro.configs.base import (  # noqa: F401
    ARCH_IDS,
    ModelConfig,
    MoEConfig,
    RunConfig,
    SHAPES,
    SSMConfig,
    ShapeConfig,
    all_configs,
    get_config,
    reduced_config,
    register,
    shape_supported,
)
