"""internvl2-2b — VLM: InternViT frontend + InternLM2-1.8b backbone
[arXiv:2404.16821].

Backbone: 24 layers, d_model 2048, 16 heads (GQA kv=8), d_ff 8192,
vocab 92553.  The InternViT-300M vision tower is a STUB per assignment:
``input_specs`` provides 256 precomputed patch-embedding tokens (448px /
patch-14 -> 1024 patches -> pixel-shuffle x0.5 -> 256 tokens) prepended to
the text sequence.  Full attention -> ``long_500k`` skipped.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2_2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_head=128,
    d_ff=8192,
    vocab_size=92553,
    norm="rms",
    frontend="vision",
    vision_tokens=256,
    supports_long_context=False,
))
