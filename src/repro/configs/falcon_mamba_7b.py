"""falcon-mamba-7b — attention-free Mamba-1 LM [arXiv:2410.05355].

64 layers, d_model 4096, SSM state 16, vocab 65024.  No FFN (the Mamba block
contains its own 2x expansion); no attention layers at all, so every shape
including ``long_500k`` is supported (decode is O(1) in context length).
"""
from repro.configs.base import ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="falcon_mamba_7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,            # unused (attention-free); head_dim set explicitly
    n_kv_heads=1,
    d_head=64,
    d_ff=0,               # Mamba block subsumes the FFN
    vocab_size=65024,
    norm="rms",
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    supports_long_context=True,
    notes="Mamba-1 arch; RMSNorm on dt/B/C as in FalconMamba omitted (noted).",
))
