"""jamba-v0.1-52b — hybrid Mamba + attention + MoE [arXiv:2403.19887].

32 layers in period-8 super-blocks: attention at in-block index 4, Mamba-1
elsewhere (1:7 attn:mamba).  MoE (16 experts, top-2) at every other layer
(odd indices), dense FFN (d_ff 14336) at even indices.  GQA kv=8,
d_model 4096, vocab 65536.  Hybrid -> ``long_500k`` RUNS (only 4 attention
layers hold a long KV cache; Mamba layers are O(1)).
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="jamba_v01_52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab_size=65536,
    norm="rms",
    hybrid_period=8,
    hybrid_attn_index=4,
    moe=MoEConfig(n_experts=16, top_k=2, n_shared=0, d_ff_expert=14336,
                  every=2, first_dense=1),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    supports_long_context=True,
))
