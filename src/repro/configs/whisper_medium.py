"""whisper-medium — encoder-decoder ASR transformer backbone [arXiv:2212.04356].

24+24 layers, d_model 1024, 16 heads, d_ff 4096, vocab 51865.  The conv
frontend is a STUB per assignment: ``input_specs`` provides precomputed
frame embeddings (1500 frames = 30 s of audio after 2x conv downsampling).
Full (quadratic) attention -> ``long_500k`` skipped.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper_medium",
    family="encdec",
    n_layers=24,           # decoder layers
    n_enc_layers=24,
    enc_seq_len=1500,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_head=64,
    d_ff=4096,
    vocab_size=51865,
    norm="layer",
    act="gelu",
    qkv_bias=True,
    frontend="audio",
    rope_theta=0.0,        # learned absolute positions, not RoPE
    supports_long_context=False,
    notes="audio frontend stubbed (precomputed frame embeddings)",
))
