"""deepseek-moe-16b — fine-grained MoE [arXiv:2401.06066].

28 layers, d_model 2048, 16 heads, vocab 102400.  Layer 0 is a dense FFN
(d_ff 10944); layers 1..27 are MoE with 64 routed experts (top-6) + 2 shared
experts, expert hidden 1408.  Full attention -> ``long_500k`` skipped.
"""
from repro.configs.base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="deepseek_moe_16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    norm="rms",
    moe=MoEConfig(
        n_experts=64, top_k=6, n_shared=2, d_ff_expert=1408,
        every=1, first_dense=1, d_ff_dense=10944),
    supports_long_context=False,
))
