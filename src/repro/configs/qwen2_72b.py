"""qwen2-72b — dense GQA transformer [arXiv:2407.10671].

80 layers, d_model 8192, 64 heads (GQA kv=8), d_ff 29568, vocab 152064,
QKV bias.  Full attention -> ``long_500k`` skipped.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2_72b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=29568,
    vocab_size=152064,
    norm="rms",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    supports_long_context=False,
))
