"""Config system: model/shape/run configs + the architecture registry.

Every assigned architecture provides a module ``repro.configs.<id>`` that
calls :func:`register` with its exact published config.  Shapes are the four
assigned input-shape cells; per-arch skips (e.g. ``long_500k`` on pure
full-attention archs) are declared on the ModelConfig and enforced here.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Optional, Sequence


# --------------------------------------------------------------------------
# Model configs
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    n_experts: int                 # routed experts
    top_k: int
    n_shared: int = 0              # always-on shared experts
    d_ff_expert: int = 0           # per-expert hidden size (0 -> use model d_ff)
    every: int = 1                 # MoE layer every `every` layers (Jamba: 2)
    first_dense: int = 0           # first N layers use a dense FFN (DeepSeek-MoE: 1)
    d_ff_dense: int = 0            # hidden size of those dense layers


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2                # d_inner = expand * d_model
    dt_rank: int = 0               # 0 -> ceil(d_model / 16)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                # 0 -> d_model // n_heads
    norm: str = "rms"              # rms | layer | nonparam
    act: str = "swiglu"            # swiglu | gelu
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0        # 0 -> full attention
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (Jamba): super-block period & which indices are attention layers
    hybrid_period: int = 0
    hybrid_attn_index: int = 4
    # encoder-decoder (Whisper)
    n_enc_layers: int = 0
    enc_seq_len: int = 0           # encoder frames (frontend stub output length)
    # modality frontend stub: none | audio | vision
    frontend: str = "none"
    vision_tokens: int = 0         # VLM: prepended patch-embedding tokens
    # which assigned shapes are supported (long_500k needs sub-quadratic attn)
    supports_long_context: bool = False
    notes: str = ""

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 512 so it shards over 16-way TP."""
        return -(-self.vocab_size // 512) * 512

    def attn_layer_indices(self) -> Sequence[int]:
        """Indices of attention layers (hybrid archs interleave SSM + attn)."""
        if self.family == "ssm":
            return ()
        if self.family == "hybrid":
            p, a = self.hybrid_period, self.hybrid_attn_index
            return tuple(i for i in range(self.n_layers) if i % p == a)
        return tuple(range(self.n_layers))

    def param_count(self) -> int:
        """Analytic parameter count (embedding + per-layer), for rooflines."""
        d, v, h = self.d_model, self.vocab_size, self.head_dim
        n_q, n_kv = self.n_heads, self.n_kv_heads
        emb = v * d * (1 if self.tie_embeddings else 2)

        def attn_params() -> int:
            qkv = d * h * (n_q + 2 * n_kv) + h * n_q * d
            if self.qkv_bias:
                qkv += h * (n_q + 2 * n_kv)
            return qkv

        def dense_ffn(d_ff: int) -> int:
            return d * d_ff * (3 if self.act == "swiglu" else 2)

        def moe_ffn(layer: int) -> tuple[int, int]:
            """(total, active) FFN params for a MoE layer index."""
            m = self.moe
            assert m is not None
            if layer < m.first_dense or ((layer - m.first_dense) % m.every != 0):
                dff = m.d_ff_dense or self.d_ff
                p = dense_ffn(dff)
                return p, p
            e = m.d_ff_expert or self.d_ff
            shared = m.n_shared * dense_ffn(e)
            routed_total = m.n_experts * dense_ffn(e)
            routed_active = m.top_k * dense_ffn(e)
            router = d * m.n_experts
            return shared + routed_total + router, shared + routed_active + router

        def ssm_params() -> int:
            s = self.ssm
            assert s is not None
            d_in = s.expand * d
            dt_rank = s.dt_rank or -(-d // 16)
            return (d * 2 * d_in            # in_proj (x and z)
                    + d_in * s.d_conv       # depthwise conv
                    + d_in * (dt_rank + 2 * s.d_state)  # x_proj
                    + dt_rank * d_in + d_in  # dt_proj
                    + d_in * s.d_state       # A_log
                    + d_in                   # D
                    + d_in * d)              # out_proj

        total = emb
        active = emb
        attn_set = set(self.attn_layer_indices())
        n_dec = self.n_layers
        for i in range(n_dec):
            mixer = attn_params() if i in attn_set else ssm_params()
            if self.moe is not None:
                ft, fa = moe_ffn(i)
            elif self.d_ff > 0:
                ft = fa = dense_ffn(self.d_ff)
            else:
                ft = fa = 0
            total += mixer + ft + 2 * d      # 2 norms
            active += mixer + fa + 2 * d
        # encoder stack (whisper): self-attn + ffn; decoder also has cross-attn
        if self.n_enc_layers:
            enc = self.n_enc_layers * (attn_params() + dense_ffn(self.d_ff) + 2 * d)
            cross = n_dec * (attn_params() + d)
            total += enc + cross
            active += enc + cross
        return total

    def active_param_count(self) -> int:
        return _active_params(self)


def _active_params(cfg: ModelConfig) -> int:
    """Active (per-token) params: MoE counts only top_k + shared experts."""
    if cfg.moe is None:
        return cfg.param_count()
    # Rebuild with a dense-equivalent: replace routed total with active subset.
    m = cfg.moe
    full = cfg.param_count()
    e = m.d_ff_expert or cfg.d_ff
    per_expert = cfg.d_model * e * (3 if cfg.act == "swiglu" else 2)
    n_moe_layers = sum(
        1 for i in range(cfg.n_layers)
        if i >= m.first_dense and (i - m.first_dense) % m.every == 0)
    inactive = n_moe_layers * (m.n_experts - m.top_k) * per_expert
    return full - inactive


# --------------------------------------------------------------------------
# Shape configs (the four assigned cells)
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_supported(model: ModelConfig, shape: ShapeConfig) -> bool:
    """Whether an (arch x shape) cell is runnable (long ctx needs sub-quadratic)."""
    if shape.name == "long_500k":
        return model.supports_long_context
    return True


# --------------------------------------------------------------------------
# Run config (training/serving hyperparameters; not part of the 40 cells)
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class RunConfig:
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    schedule: str = "cosine"       # cosine | wsd | constant
    warmup_steps: int = 100
    decay_start_frac: float = 0.8  # WSD: where decay phase begins
    total_steps: int = 1000
    param_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"
    remat: str = "full"            # none | full | dots
    microbatches: int = 1          # gradient accumulation
    grad_compression: str = "none"  # none | int8
    layout: str = "tp_fsdp"        # tp_fsdp | zero3 (pure FSDP, no TP)
    seed: int = 0


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

_REGISTRY: dict[str, ModelConfig] = {}

ARCH_IDS = (
    "falcon_mamba_7b",
    "whisper_medium",
    "deepseek_moe_16b",
    "mixtral_8x22b",
    "jamba_v01_52b",
    "qwen2_72b",
    "minicpm_2b",
    "olmo_1b",
    "glm4_9b",
    "internvl2_2b",
)


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    name = name.replace("-", "_").replace(".", "")
    if name not in _REGISTRY:
        if name in ARCH_IDS:
            importlib.import_module(f"repro.configs.{name}")
        else:  # allow fuzzy ids like "jamba-v0.1-52b"
            for arch in ARCH_IDS:
                if name in arch or arch in name:
                    importlib.import_module(f"repro.configs.{arch}")
                    name = arch
                    break
    if name not in _REGISTRY:
        raise KeyError(f"unknown architecture {name!r}; known: {sorted(ARCH_IDS)}")
    return _REGISTRY[name]


def all_configs() -> dict[str, ModelConfig]:
    for arch in ARCH_IDS:
        get_config(arch)
    return dict(_REGISTRY)


def reduced_config(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    small: dict = dict(
        n_layers=min(cfg.n_layers, 4 if cfg.family in ("hybrid",) else 2),
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads * 4 // cfg.n_heads, 4)),
        d_ff=128 if cfg.d_ff else 0,
        d_head=16,
        vocab_size=256,
        enc_seq_len=min(cfg.enc_seq_len, 16) if cfg.enc_seq_len else 0,
        n_enc_layers=min(cfg.n_enc_layers, 2) if cfg.n_enc_layers else 0,
        vision_tokens=min(cfg.vision_tokens, 8) if cfg.vision_tokens else 0,
        sliding_window=min(cfg.sliding_window, 16) if cfg.sliding_window else 0,
    )
    if cfg.moe is not None:
        small["moe"] = MoEConfig(
            n_experts=4, top_k=min(cfg.moe.top_k, 2),
            n_shared=min(cfg.moe.n_shared, 1), d_ff_expert=64,
            every=cfg.moe.every, first_dense=cfg.moe.first_dense,
            d_ff_dense=128 if cfg.moe.d_ff_dense else 0)
    if cfg.ssm is not None:
        small["ssm"] = SSMConfig(d_state=4, d_conv=4, expand=2)
    if cfg.hybrid_period:
        small["hybrid_period"] = 4
        small["hybrid_attn_index"] = 2
        small["n_layers"] = 4
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
