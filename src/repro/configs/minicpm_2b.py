"""minicpm-2b — llama-like dense LM trained with WSD schedule [arXiv:2404.06395].

40 layers, d_model 2304, 36 heads (MHA, kv=36), d_ff 5760, vocab 122753,
tied embeddings.  The WSD (warmup-stable-decay) schedule is implemented in
``repro.optim`` and selected by this arch's default RunConfig.
Full attention -> ``long_500k`` skipped.

Note: 36 heads is not divisible by the 16-way "model" axis; attention heads
are replicated across TP while the (divisible) FFN stays tensor-parallel —
see parallel/sharding.py.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="minicpm_2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_head=64,
    d_ff=5760,
    vocab_size=122753,
    norm="rms",
    tie_embeddings=True,
    supports_long_context=False,
    notes="WSD schedule (optim.schedule='wsd'); mu-p scaling omitted",
))
