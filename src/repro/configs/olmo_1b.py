"""olmo-1b — dense LM with non-parametric LayerNorm [arXiv:2402.00838].

16 layers, d_model 2048, 16 heads (MHA), d_ff 8192, vocab 50304, tied
embeddings.  Non-parametric LN = no learnable scale/bias.
Full attention -> ``long_500k`` skipped.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="olmo_1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=50304,
    norm="nonparam",
    act="swiglu",
    tie_embeddings=True,
    supports_long_context=False,
))
