"""AdamW + LR schedules (cosine / WSD / constant), global-norm clipping,
gradient accumulation.  Pure pytree functions (no optax dependency) so the
optimizer state shards exactly like the params (see launch/steps.py).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import RunConfig


class OptState(NamedTuple):
    step: jnp.ndarray          # () int32
    mu: object                 # pytree like params
    nu: object                 # pytree like params


def init_opt_state(params, run: RunConfig) -> OptState:
    dt = jnp.dtype(run.opt_state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)  # noqa: E731
    return OptState(step=jnp.zeros((), jnp.int32),
                    mu=jax.tree_util.tree_map(zeros, params),
                    nu=jax.tree_util.tree_map(zeros, params))


def schedule(run: RunConfig, step):
    """LR at ``step`` (traced-friendly)."""
    s = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    total = jnp.float32(run.total_steps)
    warm = jnp.float32(max(run.warmup_steps, 1))
    base = jnp.float32(run.learning_rate)
    warm_lr = base * jnp.minimum(s / warm, 1.0)
    if run.schedule == "constant":
        return warm_lr
    if run.schedule == "wsd":
        # warmup -> stable -> linear decay to 10% over the last segment
        decay_start = total * run.decay_start_frac
        frac = jnp.clip((s - decay_start) / jnp.maximum(total - decay_start, 1.0),
                        0.0, 1.0)
        return warm_lr * (1.0 - 0.9 * frac)
    # cosine to 10%
    prog = jnp.clip((s - warm) / jnp.maximum(total - warm, 1.0), 0.0, 1.0)
    return warm_lr * (0.55 + 0.45 * jnp.cos(jnp.pi * prog))


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


def adamw_update(params, grads, opt: OptState, run: RunConfig):
    """One AdamW step.  Returns (new_params, new_opt, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, run.grad_clip)
    step = opt.step + 1
    lr = schedule(run, step)
    b1, b2, eps, wd = run.beta1, run.beta2, run.eps, run.weight_decay
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(m.dtype)
        m = b1 * m + (1 - b1) * gf
        v = b2 * v + (1 - b2) * gf * gf
        mh = m / c1
        vh = v / c2
        delta = mh / (jnp.sqrt(vh) + eps) + wd * p.astype(m.dtype)
        return (p.astype(m.dtype) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(opt.mu)
    flat_v = jax.tree_util.tree_leaves(opt.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    return new_p, OptState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}
