"""Per-architecture smoke tests: reduced config, one forward + one train
step + a few decode steps on CPU; asserts shapes and finiteness."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import (ARCH_IDS, RunConfig, get_config, reduced_config,
                           SHAPES, shape_supported)
from repro.launch.steps import make_train_step
from repro.models import model as MDL
from repro.optim import optimizer as OPT


def _extra(cfg, B, key):
    extra = {}
    if cfg.frontend == "vision":
        extra["patches"] = jax.random.normal(
            key, (B, cfg.vision_tokens, cfg.d_model))
    if cfg.family == "encdec":
        extra["frames"] = jax.random.normal(
            key, (B, cfg.enc_seq_len, cfg.d_model))
    return extra


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch, key):
    cfg = reduced_config(get_config(arch))
    B, S = 2, 16
    params = MDL.init_model(key, cfg, jnp.float32)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    extra = _extra(cfg, B, key)

    logits, aux = MDL.forward(params, cfg, tokens, extra=extra, remat="none")
    S_out = S + (cfg.vision_tokens if cfg.frontend == "vision" else 0)
    assert logits.shape == (B, S_out, cfg.padded_vocab)
    assert jnp.isfinite(logits).all()

    run = RunConfig(param_dtype="float32", total_steps=10, warmup_steps=1)
    step = make_train_step(cfg, run)
    opt = OPT.init_opt_state(params, run)
    batch = {"tokens": tokens, "labels": tokens, **extra}
    params2, opt2, metrics = step(params, opt, batch)
    assert jnp.isfinite(metrics["loss"])
    assert int(opt2.step) == 1
    # params actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()), params, params2)
    assert max(jax.tree_util.tree_leaves(moved)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_steps(arch, key):
    cfg = reduced_config(get_config(arch))
    B = 2
    params = MDL.init_model(key, cfg, jnp.float32)
    enc_out = None
    if cfg.family == "encdec":
        frames = jax.random.normal(key, (B, cfg.enc_seq_len, cfg.d_model))
        enc_out = MDL._encode(params, cfg, frames, remat="none")
    cache = MDL.init_cache(cfg, B, 32, jnp.float32, enc_out=enc_out,
                           params=params)
    tok = jnp.zeros((B, 1), jnp.int32)
    for pos in range(4):
        logits, cache = MDL.decode_step(params, cfg, cache, tok,
                                        jnp.int32(pos))
        assert logits.shape == (B, 1, cfg.padded_vocab)
        assert jnp.isfinite(logits).all()
        tok = logits[:, -1:].argmax(-1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_exact_published_config_registered(arch):
    cfg = get_config(arch)
    # the full config instantiates ABSTRACTLY (no allocation) and its layer
    # plan covers every layer
    import functools
    shapes = jax.eval_shape(
        functools.partial(MDL.init_model, cfg=cfg, dtype=jnp.bfloat16),
        jax.random.PRNGKey(0))
    import math
    n_params = sum(math.prod(l.shape)
                   for l in jax.tree_util.tree_leaves(shapes))
    # within 3% of the analytic count (analytic ignores vocab padding)
    assert abs(n_params - cfg.param_count()) / cfg.param_count() < 0.03


def test_long_context_support_flags():
    assert get_config("falcon_mamba_7b").supports_long_context
    assert get_config("jamba_v01_52b").supports_long_context
    assert get_config("mixtral_8x22b").supports_long_context
    for a in ("qwen2_72b", "olmo_1b", "glm4_9b", "whisper_medium",
              "minicpm_2b", "internvl2_2b", "deepseek_moe_16b"):
        assert not get_config(a).supports_long_context
        assert not shape_supported(get_config(a), SHAPES["long_500k"])
