"""Layer-level properties: RoPE, norms, GQA attention equivalences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, reduced_config
from repro.kernels import ref
from repro.models import layers as L


def test_rope_preserves_norm():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 16, 4, 64))
    cos, sin = L.rope_freqs(64, 10_000.0, jnp.arange(16))
    y = L.apply_rope(x, cos, sin)
    np.testing.assert_allclose(jnp.linalg.norm(x, axis=-1),
                               jnp.linalg.norm(y, axis=-1), rtol=1e-5)


def test_rope_relative_position_property():
    """q_i . k_j after RoPE depends only on (i - j)."""
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (1, 1, 1, 64))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 1, 64))

    def dot_at(i, j):
        ci, si = L.rope_freqs(64, 10_000.0, jnp.asarray([i]))
        cj, sj = L.rope_freqs(64, 10_000.0, jnp.asarray([j]))
        qi = L.apply_rope(q, ci, si)
        kj = L.apply_rope(k, cj, sj)
        return float((qi * kj).sum())

    assert dot_at(5, 3) == pytest.approx(dot_at(12, 10), rel=1e-4)
    assert dot_at(7, 7) == pytest.approx(dot_at(0, 0), rel=1e-4)


def test_rms_norm_scale_equivariance():
    """RMSNorm(c*x) == RMSNorm(x) for c > 0 (eps-negligible regime)."""
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 32)) * 10
    p = {"scale": jnp.ones((32,))}
    a = L.apply_norm(p, x, "rms")
    b = L.apply_norm(p, 7.0 * x, "rms")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-5)


def test_nonparam_norm_zero_mean_unit_var():
    x = jax.random.normal(jax.random.PRNGKey(3), (8, 64)) * 3 + 5
    out = L.apply_norm({}, x, "nonparam")
    assert float(jnp.abs(out.mean(-1)).max()) < 1e-4
    assert float(jnp.abs(out.var(-1) - 1).max()) < 1e-2


def test_gqa_with_equal_heads_is_mha():
    """GQA ref with Hkv == Hq must equal explicit per-head attention."""
    key = jax.random.PRNGKey(4)
    q = jax.random.normal(key, (1, 8, 4, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 8, 4, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 8, 4, 16))
    out = ref.attention_ref(q, k, v, causal=True)
    # manual per-head
    for h in range(4):
        s = (q[0, :, h] @ k[0, :, h].T) / np.sqrt(16)
        mask = np.tril(np.ones((8, 8), bool))
        s = jnp.where(mask, s, -jnp.inf)
        o = jax.nn.softmax(s, -1) @ v[0, :, h]
        np.testing.assert_allclose(np.asarray(out[0, :, h]), np.asarray(o),
                                   rtol=1e-4, atol=1e-5)


def test_gqa_grouping_maps_right_kv_head():
    """With 2 kv heads, q heads 0,1 use kv 0; q heads 2,3 use kv 1."""
    key = jax.random.PRNGKey(5)
    q = jax.random.normal(key, (1, 4, 4, 8))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 4, 2, 8))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 4, 2, 8))
    out = ref.attention_ref(q, k, v, causal=False)
    kk = jnp.repeat(k, 2, axis=2)
    vv = jnp.repeat(v, 2, axis=2)
    want = ref.attention_ref(q, kk, vv, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5)


@given(st.integers(1, 3), st.integers(4, 24), st.integers(0, 1))
@settings(max_examples=15, deadline=None)
def test_sliding_window_subset_property(b, s, causal_i):
    """Windowed attention == full attention when window >= seq length."""
    key = jax.random.PRNGKey(b * 100 + s)
    q = jax.random.normal(key, (b, s, 2, 8))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, 2, 8))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, 2, 8))
    causal = bool(causal_i)
    full = ref.attention_ref(q, k, v, causal=causal, sliding_window=0)
    wide = ref.attention_ref(q, k, v, causal=causal, sliding_window=s + 5)
    np.testing.assert_allclose(np.asarray(full), np.asarray(wide), atol=1e-5)


def test_qkv_bias_changes_output():
    cfg = reduced_config(get_config("qwen2_72b"))
    key = jax.random.PRNGKey(6)
    p = L.init_attention(key, cfg, jnp.float32)
    assert "bq" in p          # qwen2 has QKV bias
    x = jax.random.normal(key, (1, 8, cfg.d_model))
    out0 = L.attention_block(p, cfg, x)
    p2 = dict(p)
    p2["bq"] = jnp.ones_like(p["bq"])
    out1 = L.attention_block(p2, cfg, x)
    assert float(jnp.abs(out0 - out1).max()) > 1e-6
