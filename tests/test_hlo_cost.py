"""Trip-count-aware HLO cost parser vs known-FLOP programs."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_cost


def _flops_of(fn, *args):
    comp = jax.jit(fn).lower(*args).compile()
    return hlo_cost.analyze_hlo(comp.as_text()).flops


def test_plain_matmul():
    a = jnp.ones((64, 128), jnp.float32)
    b = jnp.ones((128, 32), jnp.float32)
    f = _flops_of(lambda a, b: a @ b, a, b)
    assert f == pytest.approx(2 * 64 * 128 * 32, rel=0.01)


def test_scan_multiplies_by_trip_count():
    """THE reason this parser exists: XLA cost_analysis counts while bodies
    once; scan-over-layers models need trips x body."""
    w = jnp.ones((64, 64), jnp.bfloat16)
    x = jnp.ones((64, 64), jnp.bfloat16)

    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=13)
        return y.sum()

    f_mine = _flops_of(f, x, w)
    expect = 2 * 64 ** 3 * 13
    assert f_mine == pytest.approx(expect, rel=0.05)
    # and the builtin misses the trip count
    comp = jax.jit(f).lower(x, w).compile()
    xla_cost = comp.cost_analysis()
    if isinstance(xla_cost, list):      # older jax returns [dict]
        xla_cost = xla_cost[0]
    builtin = xla_cost.get("flops", 0.0)
    assert builtin < expect / 2


def test_nested_scan():
    w = jnp.ones((32, 32), jnp.float32)

    def f(w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=4)
            return c, None
        y, _ = jax.lax.scan(outer, jnp.ones((32, 32)), None, length=5)
        return y.sum()

    f_mine = _flops_of(f, w)
    assert f_mine == pytest.approx(2 * 32 ** 3 * 20, rel=0.05)


def test_collective_parse():
    txt = '''
HloModule test
ENTRY %main (p: f32[16,16]) -> f32[16,16] {
  %p = f32[16,16]{1,0} parameter(0)
  %ag = f32[16,16]{1,0} all-gather(%p), replica_groups={}, dimensions={0}
  ROOT %ar = f32[16,16]{1,0} all-reduce(%ag), to_apply=%add
}
'''
    c = hlo_cost.analyze_hlo(txt)
    assert c.coll_bytes["all-gather"] == 16 * 16 * 4
    assert c.coll_bytes["all-reduce"] == 16 * 16 * 4
    assert c.coll_weighted == 16 * 16 * 4 * 3  # AR weighted 2x


def test_model_flops_match_analytic():
    """End-to-end: a 4-layer dense LM's parsed train FLOPs within 2x of
    the 8ND analytic estimate (remat + attention + vocab overhead)."""
    import dataclasses
    from repro.configs import get_config, reduced_config
    from repro.configs.base import RunConfig
    from repro.launch.steps import make_train_step
    from repro.models import model as MDL
    from repro.optim import optimizer as OPT

    cfg = dataclasses.replace(reduced_config(get_config("olmo_1b")),
                              n_layers=4, d_model=128, d_ff=512,
                              vocab_size=512, n_heads=4, n_kv_heads=4,
                              d_head=32)
    run = RunConfig(param_dtype="float32")
    params = MDL.init_model(jax.random.PRNGKey(0), cfg, jnp.float32)
    opt = OPT.init_opt_state(params, run)
    B, S = 4, 64
    batch = {"tokens": jnp.zeros((B, S), jnp.int32),
             "labels": jnp.zeros((B, S), jnp.int32)}
    step = make_train_step(cfg, run)
    comp = jax.jit(step).lower(params, opt, batch).compile()
    parsed = hlo_cost.analyze_hlo(comp.as_text()).flops
    n = cfg.param_count()
    analytic = 8 * n * B * S
    assert analytic / 2 < parsed < analytic * 3, (parsed, analytic)
