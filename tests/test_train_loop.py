"""End-to-end training loop: loss goes down; crash + resume continuity;
int8 gradient compression trains equivalently."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import RunConfig, get_config, reduced_config
from repro.launch.train import train

CFG = reduced_config(get_config("olmo_1b"))
RUN = RunConfig(param_dtype="float32", learning_rate=1e-3, total_steps=30,
                warmup_steps=2, schedule="constant")
quiet = lambda *a, **k: None  # noqa: E731


def test_loss_decreases():
    _, _, losses = train(CFG, RUN, steps=30, batch=4, seq=32, verbose=quiet,
                         log_every=5)
    first, last = losses[0][1], losses[-1][1]
    assert last < first - 0.3, (first, last)


def test_crash_resume_matches_uninterrupted(tmp_path):
    """Kill at step 20, resume from the step-10 checkpoint: the final
    params must match an uninterrupted run bit-for-bit (deterministic data
    + deterministic optimizer)."""
    ckpt_a = str(tmp_path / "a")
    params_ref, _, _ = train(CFG, RUN, steps=30, batch=4, seq=32,
                             ckpt_dir=str(tmp_path / "ref"), ckpt_every=10,
                             verbose=quiet)
    with pytest.raises(RuntimeError, match="injected failure"):
        train(CFG, RUN, steps=30, batch=4, seq=32, ckpt_dir=ckpt_a,
              ckpt_every=10, fail_at=20, verbose=quiet)
    params_res, _, _ = train(CFG, RUN, steps=30, batch=4, seq=32,
                             ckpt_dir=ckpt_a, ckpt_every=10, resume=True,
                             verbose=quiet)
    import jax
    for a, b in zip(jax.tree_util.tree_leaves(params_ref),
                    jax.tree_util.tree_leaves(params_res)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=0, atol=0)


def test_int8_compression_trains():
    run = dataclasses.replace(RUN, grad_compression="int8")
    _, _, losses = train(CFG, run, steps=30, batch=4, seq=32, verbose=quiet,
                         log_every=5)
    assert losses[-1][1] < losses[0][1] - 0.25


def test_microbatched_equals_full_batch():
    """Gradient accumulation is loss-preserving for the mean-loss objective."""
    run1 = dataclasses.replace(RUN, total_steps=5)
    run2 = dataclasses.replace(RUN, total_steps=5, microbatches=2)
    p1, _, l1 = train(CFG, run1, steps=5, batch=4, seq=32, verbose=quiet,
                      log_every=1)
    p2, _, l2 = train(CFG, run2, steps=5, batch=4, seq=32, verbose=quiet,
                      log_every=1)
    import jax
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)
