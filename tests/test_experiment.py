"""Unified declarative Experiment API (core/experiment.py, DESIGN.md §12):
planner partitioning, compile accounting, dispatch fallback, bitwise
reproduction of every frozen golden through ExperimentSpec.run(), spec
provenance round-trips, and the single-implementation metric contract."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import experiment as E
from repro.core import metrics as M
from repro.core import sim as SIM
from repro.core import sweep as SW
from repro.core import workloads as W
from repro.core.experiment import ExperimentSpec, WorkloadSpec
from repro.core.sim import SimParams, SimPolicy, run

from test_sweep import (_FIG3B_SPOT_BEACONS, _FIG3B_SPOT_SHA,
                        _GOLDEN_APP_DONE_SHA, _GOLDEN_BEACONS)


def _params(k=4, **kw):
    kw.setdefault("m", 16)
    kw.setdefault("n_childs", 16)
    kw.setdefault("max_apps", 32)
    kw.setdefault("queue_cap", 512)
    return SimParams(k=k, **kw)


# --------------------------------------------------------------------------
# Satellite: single metric implementation, re-exported
# --------------------------------------------------------------------------

def test_metric_import_paths_resolve_to_same_function():
    """sim.py and sweep.py re-export the metrics module's functions —
    one implementation, not three drifting copies."""
    for name in ("response_times", "speedup", "mean_response", "beacons",
                 "beacons_rx", "mgmt_msgs", "mgmt_latency", "mgmt_proc"):
        assert getattr(SIM, name) is getattr(M, name), name
        assert getattr(SW, name) is getattr(M, name), name


def test_metrics_shape_polymorphic():
    """The unified metrics accept both unbatched run() states and
    batched sweep states."""
    p = _params()
    arr, gmns, lens = W.independent_tasks(p, n_apps=1)
    st = run(p, arr, gmns, lens, 1e7)
    s_scalar = M.speedup(st, lens)
    assert s_scalar.shape == ()
    assert 1.0 < float(s_scalar) <= p.m
    wl = W.independent_batch(p, seeds=(0,), n_apps=1)
    stb = SW.sweep(p.shape, SW.knob_batch(dn_th=(4, 8)), wl, 1e7)
    s_grid = M.speedup(stb, wl[2])
    assert s_grid.shape == (2, 1)
    assert float(s_grid[0, 0]) == float(s_scalar)


# --------------------------------------------------------------------------
# Planner
# --------------------------------------------------------------------------

def test_planner_grouping_is_minimal():
    """No two groups share a static combo, even when the axes contain
    duplicates; order is first-seen."""
    p = _params()
    spec = ExperimentSpec(base=p,
                          topologies=("ideal", "hier_tree", "ideal"),
                          policies=(("min_search", "threshold"),
                                    ("round_robin", "periodic"),
                                    ("min_search", "threshold")),
                          sim_len=1e5)
    plan = spec.plan()
    combos = [(c.shape, c.policy, c.topology) for c in plan.combos]
    assert len(combos) == len(set(combos)) == 4   # 2 policies x 2 topologies
    assert plan.combos[0].policy.mapping == "min_search"
    assert plan.combos[0].topology.kind == "ideal"


def test_planner_queue_impl_axis_folds_into_shape():
    spec = ExperimentSpec(base=_params(), queue_impls=("linear", "tree"),
                          sim_len=1e5)
    plan = spec.plan()
    assert [c.shape.queue_impl for c in plan.combos] == ["linear", "tree"]
    assert plan.n_groups == 2


def test_planner_expected_programs():
    spec = ExperimentSpec(base=_params(),
                          topologies=("ideal", "mesh2d"),
                          knobs={"dn_th": (1, 2, 4)},
                          workloads=(WorkloadSpec("interference",
                                                  seeds=(0, 1)),
                                     WorkloadSpec("bursty", seeds=(0,))),
                          sim_len=1e5)
    plan = spec.plan()
    assert plan.n_groups == 2
    assert plan.expected_programs("seq") == 2
    # vmap specializes on the lane count too: S=2 and S=1 each compile
    assert plan.expected_programs("vmap") == 4


def test_cache_grows_by_exactly_group_count_on_fresh_cache():
    """The one-XLA-program-per-group guarantee, measured: a spec over
    never-before-compiled shapes adds exactly n_groups cache entries."""
    # m=12/k=3 with queue_cap=384 is used nowhere else in the suite, so
    # the jit cache cannot have these combos warm
    base = SimParams(m=12, k=3, n_childs=6, max_apps=16, queue_cap=384)
    spec = ExperimentSpec(base=base,
                          topologies=("ideal", "hier_tree"),
                          policies=(("hashed_random", "periodic"),
                                    ("round_robin", "threshold")),
                          knobs={"dn_th": (2, 4)},
                          workloads=(WorkloadSpec("interference",
                                                  seeds=(0,)),),
                          sim_len=1e5)
    c0 = SW.cache_size()
    frame = spec.run(mode="seq")
    assert SW.cache_size() - c0 == spec.plan().n_groups == 4
    assert frame.compiles == 4
    # re-running the same spec compiles nothing new
    frame2 = spec.run(mode="seq")
    assert frame2.compiles == 0


def test_pmap_falls_back_cleanly_on_single_device():
    """dispatch="pmap" on a single-device backend degrades to the auto
    choice (seq on CPU) with identical results."""
    import jax
    if jax.device_count() > 1:
        pytest.skip("host unexpectedly exposes multiple devices")
    p = _params()
    spec = ExperimentSpec(base=p, knobs={"dn_th": (1, 4)},
                          workloads=(WorkloadSpec("interference",
                                                  seeds=(0,)),),
                          sim_len=2e5)
    fp = spec.run(mode="pmap")
    fs = spec.run(mode="seq")
    assert fp.mode_requested == "pmap"
    assert fp.mode in ("seq", "vmap")
    a, b = fp.state(), fs.state()
    assert all(np.array_equal(a[k], b[k]) for k in a)


def test_pmap_dispatches_across_forced_host_devices():
    """With XLA forced to expose 2 host devices, pmap dispatch really
    places groups on distinct devices and stays bitwise with seq."""
    code = textwrap.dedent("""
        import numpy as np, jax
        from repro.core.experiment import ExperimentSpec, WorkloadSpec
        from repro.core.sim import SimParams
        assert jax.device_count() == 2, jax.device_count()
        p = SimParams(m=16, k=4, n_childs=16, max_apps=32, queue_cap=512)
        spec = ExperimentSpec(base=p, topologies=("ideal", "hier_tree"),
                              knobs={"dn_th": (1, 4)},
                              workloads=(WorkloadSpec("interference",
                                                      seeds=(0,)),),
                              sim_len=2e5)
        fp = spec.run(mode="pmap")
        fs = spec.run(mode="seq")
        assert fp.mode == "pmap"
        for topo in ("ideal", "hier_tree"):
            a, b = fp.state(topology=topo), fs.state(topology=topo)
            assert all(np.array_equal(a[k], b[k]) for k in a), topo
        print("PMAP_BITWISE_OK")
    """)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=2",
               PYTHONPATH="src" + os.pathsep + os.environ.get("PYTHONPATH",
                                                              ""))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         cwd=os.path.join(os.path.dirname(__file__), ".."),
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "PMAP_BITWISE_OK" in out.stdout


# --------------------------------------------------------------------------
# Bitwise golden gates through ExperimentSpec.run()
# --------------------------------------------------------------------------

def test_spec_reproduces_pr2_golden_grid_bitwise():
    """The frozen PR-2 golden grid (beacons + app_done sha) through the
    declarative surface."""
    import hashlib
    spec = ExperimentSpec(base=_params(), knobs={"dn_th": (1, 2, 4, 8)},
                          workloads=(WorkloadSpec("interference",
                                                  seeds=(0, 1)),),
                          sim_len=3e5)
    frame = spec.run()
    st = frame.state()
    assert np.asarray(st["beacons_tx"]).tolist() == _GOLDEN_BEACONS
    done = np.asarray(st["app_done"], np.float32)
    assert hashlib.sha256(done.tobytes()).hexdigest() == _GOLDEN_APP_DONE_SHA


def test_spec_reproduces_fig3b_spot_golden_bitwise():
    """The fig3b-shaped spot grid (captured at 137008a) through the
    declarative surface."""
    import hashlib
    spec = ExperimentSpec(
        base=SimParams(m=64, k=16, n_childs=50, max_apps=128,
                       queue_cap=2048),
        knobs={"dn_th": (1, 2, 4, 8, 16, 32)},
        workloads=(WorkloadSpec("interference", seeds=(1,)),),
        sim_len=1e6)
    frame = spec.run()
    st = frame.state()
    assert np.asarray(st["beacons_tx"]).tolist() == _FIG3B_SPOT_BEACONS
    done = np.asarray(st["app_done"], np.float32)
    assert hashlib.sha256(done.tobytes()).hexdigest() == _FIG3B_SPOT_SHA


def test_spec_tree_matches_linear_bitwise_via_queue_axis():
    """The tree==linear contract through the declarative queue_impls
    axis, on a non-ideal fabric that stresses the bulk push."""
    spec = ExperimentSpec(base=_params(), queue_impls=("linear", "tree"),
                          topologies=("hier_tree",),
                          knobs={"dn_th": (1, 4)},
                          workloads=(WorkloadSpec("interference",
                                                  seeds=(0,)),),
                          sim_len=3e5)
    frame = spec.run()
    lin = frame.state(queue_impl="linear")
    tre = frame.state(queue_impl="tree")
    for key in ("app_done", "app_arrive", "beacons_tx", "beacons_rx",
                "events_processed", "dropped", "mgmt_msgs", "mgmt_latency",
                "mgmt_proc"):
        assert np.array_equal(lin[key], tre[key]), key


def test_spec_matches_legacy_sweep_entry_points_bitwise():
    """A cross-axis spec agrees leaf-for-leaf with the deprecated
    sweep_policies/sweep_topologies shims fed the same grid."""
    p = _params()
    pols = (SimPolicy("min_search", "threshold"),
            SimPolicy("round_robin", "periodic"))
    spec = ExperimentSpec(base=p, policies=pols,
                          topologies=("ideal", "hier_tree"),
                          knobs={"dn_th": (2, 8)},
                          workloads=(WorkloadSpec("interference",
                                                  seeds=(0,)),),
                          sim_len=2e5)
    frame = spec.run()
    wl = W.interference_batch(p, seeds=(0,), sim_len=2e5)
    kn = SW.knob_batch(dn_th=(2, 8))
    with pytest.deprecated_call():
        by_pol = SW.sweep_policies(p.shape, kn, wl, policies=pols,
                                   sim_len=2e5, topology="hier_tree")
    with pytest.deprecated_call():
        by_topo = SW.sweep_topologies(p.shape, kn, wl,
                                      topologies=("ideal", "hier_tree"),
                                      sim_len=2e5)
    for pol in pols:
        a = frame.state(mapping=pol.mapping, beacon=pol.beacon,
                        topology="hier_tree")
        b = by_pol[(pol.mapping, pol.beacon)]
        assert all(np.array_equal(a[k], np.asarray(b[k])) for k in a)
    for kind in ("ideal", "hier_tree"):
        a = frame.state(mapping="min_search", beacon="threshold",
                        topology=kind)
        b = by_topo[kind]
        assert all(np.array_equal(a[k], np.asarray(b[k])) for k in a)


# --------------------------------------------------------------------------
# ResultFrame: columns, rows, provenance round-trip
# --------------------------------------------------------------------------

def test_resultframe_columns_aligned_and_ordered():
    spec = ExperimentSpec(base=_params(), shapes=(2, 4),
                          knobs={"dn_th": (1, 4)},
                          workloads=(WorkloadSpec("interference",
                                                  seeds=(0, 1)),),
                          sim_len=2e5)
    frame = spec.run()
    assert len(frame) == 2 * 2 * 2                # shapes x B x S
    assert frame.col("k").tolist() == [2] * 4 + [4] * 4
    assert frame.col("dn_th").tolist() == [1, 1, 4, 4] * 2
    assert frame.col("seed").tolist() == [0, 1] * 4
    # selection sugar matches manual masking
    sel = frame.mean_response(k=4, dn_th=4)
    man = frame.col("mean_response")[(frame.col("k") == 4)
                                     & (frame.col("dn_th") == 4)]
    assert np.array_equal(sel, man, equal_nan=True)
    # every metric accessor returns an aligned (N,) column
    for acc in (frame.beacons_tx, frame.beacons_rx, frame.mgmt_msgs,
                frame.mgmt_latency, frame.mgmt_proc, frame.speedup):
        assert acc().shape == (len(frame),)


def test_mask_rounds_float_knob_selectors_through_float32():
    """Knob columns hold float32 values; a float selector not exactly
    representable in f32 (e.g. 0.1) must still match its lane."""
    spec = ExperimentSpec(base=_params(), knobs={"c_s": (0.1, 8.0)},
                          workloads=(WorkloadSpec("interference",
                                                  seeds=(0,)),),
                          sim_len=1e5)
    frame = spec.run()
    assert frame.mask(c_s=0.1).sum() == 1
    assert frame.speedup(c_s=0.1).shape == (1,)
    # generated accessors cover every metric column
    assert frame.dropped().shape == (2,)
    assert frame.events(c_s=8.0).shape == (1,)
    assert np.array_equal(frame.metric("beacons_tx"), frame.beacons_tx())


def test_resultframe_payload_json_roundtrip():
    spec = ExperimentSpec(base=_params(), knobs={"dn_th": (2,)},
                          workloads=(WorkloadSpec("interference",
                                                  seeds=(0,)),),
                          sim_len=1e5)
    frame = spec.run()
    payload = frame.to_payload()
    back = json.loads(json.dumps(payload, default=float))
    assert back["rows"] == json.loads(json.dumps(frame.rows(),
                                                 default=float))
    assert back["experiment"]["n_groups"] == 1
    spec2 = E.spec_from_dict(back["spec"])
    assert spec2.to_dict() == json.loads(json.dumps(spec.to_dict()))
    # the reconstructed spec reproduces the same results bitwise
    st2 = spec2.run().state()
    st = frame.state()
    assert all(np.array_equal(st[k], st2[k]) for k in st)


def test_raw_workload_spec_provenance_and_errors():
    p = _params()
    wl = W.interference_batch(p, seeds=(0,), sim_len=1e5)
    w = WorkloadSpec.raw(wl)
    d = w.to_dict()
    assert d["raw"]["shapes"][0] == [1, p.max_apps]
    assert len(d["raw"]["sha256"]) == 64
    with pytest.raises(ValueError, match="cannot be reconstructed"):
        E.spec_from_dict({"workloads": [d], "base": {}, "shapes": [],
                          "policies": [], "topologies": [], "knobs": {},
                          "sim_len": 1e5, "mode": "auto"})
    with pytest.raises(ValueError, match="unknown workload kind"):
        WorkloadSpec("nope")
    with pytest.raises(ValueError, match="unknown knob axes"):
        ExperimentSpec(base=p, knobs={"warp": (1,)})
    with pytest.raises(ValueError, match="unknown mode"):
        ExperimentSpec(base=p, mode="warp")


def test_scenario_axis_multiple_workload_specs():
    """Several WorkloadSpecs ride one spec as the scenario axis; lanes
    keep their per-scenario metadata."""
    spec = ExperimentSpec(
        base=_params(),
        knobs={"dn_th": (2,)},
        workloads=(WorkloadSpec("interference", seeds=(0,)),
                   WorkloadSpec.make("hotspot", seeds=(0,), hot_frac=0.9)),
        sim_len=2e5)
    frame = spec.run()
    assert len(frame) == 2
    assert frame.col("workload").tolist() == ["interference", "hotspot"]
    st_hot = frame.state(workload_index=1)
    assert np.asarray(st_hot["events_processed"]).sum() > 0


# --------------------------------------------------------------------------
# Faults axis (DESIGN.md §13) and the strict schema-v5 reader
# --------------------------------------------------------------------------

def test_faults_axis_crosses_groups_and_fills_metrics():
    """The faults axis crosses every group, adds at most one extra
    program per group (schedules padded to one length per k), labels the
    ``fault`` coordinate, and zero-fills the availability metrics on
    no-fault rows."""
    from repro.core.faults import FaultSpec
    # m=12/k=4 with queue_cap=320 is used nowhere else in the suite, so
    # the jit cache cannot have the no-fault program for this combo warm
    p = SimParams(m=12, k=4, n_childs=6, max_apps=16, queue_cap=320)
    spec = ExperimentSpec(
        base=p, shapes=(4,), topologies=("hier_tree",),
        knobs={"dn_th": (2,)},
        workloads=(WorkloadSpec(seeds=(0,)),),
        faults=(None,
                FaultSpec.poisson_links(rate=3e-4, repair=3e4, seed=2),
                FaultSpec.partition(t_down=8e4, t_heal=1.5e5, name="part")),
        sim_len=2e5, mode="seq")
    frame = spec.run()
    assert frame.compiles == frame.expected_programs == 2
    assert sorted(set(frame.col("fault"))) \
        == ["none", "part", "poisson_links"]
    assert (frame.msgs_lost(fault="none") == 0).all()
    assert frame.msgs_lost(fault="poisson_links").sum() > 0
    assert (frame.downtime(fault="part") > 0).all()
    # the no-fault group is the bitwise anchor: same leaves as a bare run
    wl = W.interference_batch(p, seeds=(0,), sim_len=2e5)
    st = SW.sweep(p.shape, SW.knob_batch(dn_th=(2,)), wl, 2e5,
                  topology="hier_tree")
    anchor = frame.state(topology="hier_tree", fault="none")
    for key in ("app_done", "beacons_tx", "beacons_rx"):
        assert np.array_equal(np.asarray(st[key]), anchor[key]), key


def test_faults_axis_roundtrips_and_validates():
    from repro.core.faults import FaultSpec
    spec = ExperimentSpec(
        base=_params(), shapes=(4,), knobs={"dn_th": (2,)},
        faults=(None, FaultSpec.gmn_churn(rate=1e-5, seed=3)),
        sim_len=1e5)
    spec2 = E.spec_from_dict(spec.to_dict())
    assert spec2.faults == spec.faults
    with pytest.raises(TypeError):
        ExperimentSpec(base=_params(), faults=("poisson_links",))
    # v1 payloads (no faults key) default to the no-fault axis
    d = spec.to_dict()
    del d["faults"]
    assert E.spec_from_dict(d).faults == (None,)


def test_spec_from_dict_rejects_unknown_fields():
    """Regression (ISSUE 6 satellite): a payload written by a newer
    schema — e.g. a v5 results file with an axis this reader does not
    know — must error loudly, not silently reconstruct a spec that runs
    different experiments than the payload records."""
    spec = ExperimentSpec(base=_params(), shapes=(4,),
                          knobs={"dn_th": (2,)}, sim_len=1e5)
    d = spec.to_dict()
    assert E.spec_from_dict(d) is not None          # clean payload reads
    with pytest.raises(ValueError, match="thermal_model"):
        E.spec_from_dict(dict(d, thermal_model="on"))
    with pytest.raises(ValueError, match="version"):
        E.spec_from_dict(dict(d, version=E.SPEC_VERSION + 1))
