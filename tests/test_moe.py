"""MoE layer: routing math, capacity semantics, FLOP scaling."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.configs.base import MoEConfig
from repro.models import moe as MOE
from repro.models.moe import moe_layer_indices


def _cfg(**kw):
    base = reduced_config(get_config("mixtral_8x22b"))
    if kw:
        base = dataclasses.replace(base, moe=dataclasses.replace(base.moe, **kw))
    return base


def test_top1_single_expert_equals_dense(key):
    """E=1, top-1, no shared: MoE must equal that expert's SwiGLU exactly
    (gate weight renormalizes to 1)."""
    cfg = _cfg(n_experts=1, top_k=1, n_shared=0)
    p = MOE.init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 2), (2, 8, cfg.d_model))
    out, aux = MOE.apply_moe(p, cfg, x, capacity_factor=4.0)
    h = jax.nn.silu(x @ p["wg"][0]) * (x @ p["wu"][0])
    want = h @ p["wd"][0]
    assert jnp.abs(out - want).max() < 1e-4
    assert float(aux["dropped_frac"]) == 0.0


def test_gates_renormalized(key):
    cfg = _cfg()
    p = MOE.init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(key, (1, 16, cfg.d_model)) * 5
    out, aux = MOE.apply_moe(p, cfg, x, capacity_factor=8.0)
    assert jnp.isfinite(out).all()
    assert float(aux["dropped_frac"]) == 0.0   # huge capacity: no drops


def test_capacity_drops_tokens(key):
    """capacity_factor ~0 forces drops; dropped tokens contribute zero."""
    cfg = _cfg(n_shared=0)
    p = MOE.init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(key, (1, 32, cfg.d_model))
    out_lo, aux_lo = MOE.apply_moe(p, cfg, x, capacity_factor=0.01)
    out_hi, aux_hi = MOE.apply_moe(p, cfg, x, capacity_factor=8.0)
    assert float(aux_lo["dropped_frac"]) > float(aux_hi["dropped_frac"])
    # with capacity 1 per expert some token rows are exactly zero
    zeros = (jnp.abs(out_lo).max(-1) == 0).sum()
    assert int(zeros) > 0


def test_shared_expert_always_on(key):
    cfg = reduced_config(get_config("deepseek_moe_16b"))
    p = MOE.init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(key, (1, 8, cfg.d_model))
    out_full, _ = MOE.apply_moe(p, cfg, x, capacity_factor=4.0)
    # zero the routed experts: output must equal the shared path alone
    p0 = dict(p)
    for k in ("wg", "wu", "wd"):
        p0[k] = jnp.zeros_like(p[k])
    out_shared, _ = MOE.apply_moe(p0, cfg, x, capacity_factor=4.0)
    from repro.models.layers import apply_mlp
    want = apply_mlp(p["shared"], cfg, x.reshape(8, -1)).reshape(1, 8, -1)
    assert jnp.abs(out_shared - want).max() < 1e-4
    assert jnp.abs(out_full - out_shared).max() > 1e-4  # routed adds signal


def test_moe_layer_indices_patterns():
    ds = get_config("deepseek_moe_16b")
    idx = moe_layer_indices(ds)
    assert 0 not in idx and 1 in idx and len(idx) == 27
    jm = get_config("jamba_v01_52b")
    idx = moe_layer_indices(jm)
    assert idx == {i for i in range(32) if i % 2 == 1}


def test_load_balance_loss_uniform_is_one(key):
    """Perfectly uniform routing gives load_balance == 1 (Switch norm)."""
    cfg = _cfg(n_experts=4, top_k=1, n_shared=0)
    p = MOE.init_moe(key, cfg, jnp.float32)
    p = dict(p)
    p["router"] = jnp.zeros_like(p["router"])   # uniform probs
    x = jax.random.normal(key, (1, 64, cfg.d_model))
    _, aux = MOE.apply_moe(p, cfg, x, capacity_factor=8.0)
    assert float(aux["load_balance"]) == pytest.approx(1.0, rel=0.05)
