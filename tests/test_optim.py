"""Optimizer: AdamW convergence, schedules, clipping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import RunConfig
from repro.optim import optimizer as OPT


def test_adamw_minimizes_quadratic():
    run = RunConfig(learning_rate=0.1, weight_decay=0.0, schedule="constant",
                    warmup_steps=1, total_steps=200, grad_clip=1e9)
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = OPT.init_opt_state(params, run)
    loss = lambda p: jnp.sum(p["w"] ** 2)  # noqa: E731
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt, _ = OPT.adamw_update(params, g, opt, run)
    assert float(loss(params)) < 1e-3


def test_grad_clip():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, gn = OPT.clip_by_global_norm(g, 1.0)
    assert float(gn) > 100
    total = jnp.sqrt(sum(jnp.sum(x ** 2)
                         for x in jax.tree_util.tree_leaves(clipped)))
    assert abs(float(total) - 1.0) < 1e-5


@pytest.mark.parametrize("sched", ["cosine", "wsd", "constant"])
def test_schedule_shapes(sched):
    run = RunConfig(learning_rate=1e-3, schedule=sched, warmup_steps=10,
                    total_steps=100, decay_start_frac=0.8)
    lrs = [float(OPT.schedule(run, jnp.int32(s))) for s in range(101)]
    assert lrs[0] < lrs[10] * 0.2            # warmup
    assert abs(lrs[10] - 1e-3) < 1e-9        # peak
    if sched == "constant":
        assert lrs[-1] == pytest.approx(1e-3)
    if sched == "wsd":
        # stable plateau until 80%, then linear decay
        assert lrs[50] == pytest.approx(1e-3)
        assert lrs[79] == pytest.approx(1e-3)
        assert lrs[100] < lrs[80]
        assert lrs[100] == pytest.approx(1e-4, rel=0.1)
    if sched == "cosine":
        assert lrs[100] == pytest.approx(1e-4, rel=0.1)
        assert lrs[55] < lrs[30]


def test_wsd_vs_cosine_mid_training():
    """The WSD selling point: full LR deep into training."""
    wsd = RunConfig(schedule="wsd", warmup_steps=10, total_steps=100)
    cos = RunConfig(schedule="cosine", warmup_steps=10, total_steps=100)
    mid = jnp.int32(60)
    assert float(OPT.schedule(wsd, mid)) > float(OPT.schedule(cos, mid))
