"""Threshold beacon state machine (paper Sec 4.2)."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import beacons as B


def test_fires_on_threshold():
    s = B.BeaconState.create(k=4, dn_th=4)
    s = B.update(s, 0, 3)
    assert s.tx_count == 0                 # below threshold
    s = B.update(s, 0, 4)
    assert s.tx_count == 1
    assert (s.view[:, 0] == 4).all()       # every node received
    s = B.update(s, 0, 6)
    assert s.tx_count == 1                 # drift 2 < 4


def test_k1_never_broadcasts():
    s = B.BeaconState.create(k=1, dn_th=1)
    for load in (5, 50, 500):
        s = B.update(s, 0, load)
    assert s.tx_count == 0


@given(st.lists(st.integers(0, 200), min_size=1, max_size=200),
       st.integers(1, 32))
@settings(max_examples=50, deadline=None)
def test_beacon_count_bounded_by_total_drift(loads, dn_th):
    """#broadcasts <= total load variation / dn_th (+1)."""
    s = B.BeaconState.create(k=2, dn_th=dn_th)
    prev = 0
    drift = 0
    for ld in loads:
        s = B.update(s, 0, ld)
        drift += abs(ld - prev)
        prev = ld
    assert s.tx_count <= drift // dn_th + 1
    # view error vs truth bounded by threshold after last update
    assert abs(int(s.view[1, 0]) - loads[-1]) < dn_th


@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 50)),
                min_size=1, max_size=100))
@settings(max_examples=30, deadline=None)
def test_staleness_bounded(updates):
    s = B.BeaconState.create(k=4, dn_th=5)
    true = np.zeros(4, np.int64)
    for node, load in updates:
        s = B.update(s, node, load)
        true[node] = load
    assert B.staleness(s, true) < 5
