"""Tournament-tree event queue (core/eventq.py, DESIGN.md §11):
pop order equals sorted order under ties, incremental path repair equals
full rebuild, the argmin lowest-index tie-break contract, drop parity
with the linear impl, and vmap == seq bitwise under queue_impl="tree"."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import eventq as EQ
from repro.core import sweep as SW
from repro.core import workloads as W
from repro.core.sim import SimParams

INF = float(EQ.INF)

_jit_pop = jax.jit(EQ.pop, static_argnums=1)
_jit_push = jax.jit(EQ.bulk_push, static_argnums=(3, 7, 8))


def _times(q, cap):
    """Per-slot event times from the tree's leaf rows (INF = free)."""
    return np.asarray(EQ.leaf_times(q))[:cap]


def _from_times(cap, times):
    """Standalone queue state whose slots hold ``times`` (INF = free)."""
    q = dict(EQ.empty(cap))
    q["evq_tree"] = EQ.build_tree(jnp.asarray(times, jnp.float32))
    return q


def _push(q, times, mask=None, typ=1, cap=None):
    n = len(times)
    times = jnp.asarray(times, jnp.float32)
    mask = jnp.ones((n,), bool) if mask is None else jnp.asarray(mask, bool)
    z = jnp.zeros((n,), jnp.int32)
    cap = cap or (np.asarray(EQ.leaf_times(q)).shape[0])
    return _jit_push(q, mask, times, typ, z, z, z, EQ.tree_depth(cap), cap)


def _drain(q, depth):
    """Pop until empty; returns [(t, slot), ...]."""
    out = []
    while float(EQ.peek_time(q)) < INF:
        q, t, slot, typ, a = _jit_pop(q, depth)
        out.append((float(t), int(slot)))
    return q, out


def test_pop_order_is_sorted_with_ties():
    """Pops come out sorted by (time, slot) — the argmin rule — including
    heavy timestamp ties."""
    rng = np.random.default_rng(0)
    cap = 128
    d = EQ.tree_depth(cap)
    times = rng.integers(0, 8, size=100).astype(np.float32)  # many ties
    q = _push(EQ.empty(cap), times, cap=cap)
    q, popped = _drain(q, d)
    assert len(popped) == 100
    # push order == slot order here (fresh queue), so expected pop order
    # sorts by (time, slot)
    expect = sorted((t, s) for s, t in enumerate(times.tolist()))
    assert popped == [(t, s) for t, s in expect]
    assert int(q["dropped"]) == 0
    # drained: every slot free again
    assert bool((_times(q, cap) >= INF).all())


def test_pop_returns_payload():
    """The popped root row carries the event payload exactly."""
    cap = 64
    q = _jit_push(EQ.empty(cap), jnp.ones((2,), bool),
                  jnp.asarray([9.0, 7.0], jnp.float32), 3,
                  jnp.asarray([5, 11], jnp.int32),
                  jnp.asarray([6, 22], jnp.int32),
                  jnp.asarray([8, 33], jnp.int32),
                  EQ.tree_depth(cap), cap)
    _, t, slot, typ, a = _jit_pop(q, EQ.tree_depth(cap))
    assert (float(t), int(slot), int(typ)) == (7.0, 1, 3)
    assert np.asarray(a).tolist() == [11, 22, 33]


@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([32, 100, 128]))
@settings(max_examples=10, deadline=None)
def test_interleaved_push_pop_matches_heap(seed, cap):
    """Random interleaving of batch pushes and pops behaves as a priority
    queue with (time, slot) ordering; the tree always equals a full
    rebuild from its own leaf rows."""
    rng = np.random.default_rng(seed)
    d = EQ.tree_depth(cap)
    q = EQ.empty(cap)
    live = {}                               # slot -> time (host reference)
    for _ in range(6):
        n = int(rng.integers(1, 12))
        times = rng.integers(0, 50, size=n).astype(np.float32)
        mask = rng.random(n) < 0.8
        before_free = sorted(s for s in range(cap) if s not in live)
        q = _push(q, times, mask=mask, cap=cap)
        for j, s in zip(np.flatnonzero(mask), before_free):
            live[int(s)] = float(times[j])
        for _ in range(int(rng.integers(0, 8))):
            if not live:
                break
            q, t, slot, _, _ = _jit_pop(q, d)
            exp_t = min(live.values())
            exp_s = min(s for s, tv in live.items() if tv == exp_t)
            assert (float(t), int(slot)) == (exp_t, exp_s)
            del live[exp_s]
        # the incremental repairs must equal a from-scratch rebuild (on
        # the ordering columns; payload columns checked via behavior)
        rebuilt = EQ.build_tree(jnp.asarray(_times(q, cap)))
        assert np.array_equal(np.asarray(rebuilt)[:, :2],
                              np.asarray(q["evq_tree"])[:, :2])
        assert np.array_equal(np.asarray(EQ.build_freecnt(
            _times(q, cap) >= INF)), np.asarray(EQ.freecnt(q)))


def test_bulk_push_path_repair_equals_full_rebuild():
    """After a large masked batch lands, only the touched paths were
    repaired — and the result is identical to rebuilding the whole tree
    from its own leaf rows (payloads included)."""
    rng = np.random.default_rng(3)
    cap = 256
    q = _push(EQ.empty(cap), rng.uniform(1, 1e6, 200).astype(np.float32),
              typ=2, cap=cap)
    d = EQ.tree_depth(cap)
    for _ in range(30):                     # free up scattered slots
        q, _, _, _, _ = _jit_pop(q, d)
    times = rng.uniform(1, 1e6, 64).astype(np.float32)
    q = _push(q, times, mask=rng.random(64) < 0.5, typ=2, cap=cap)
    lt = jnp.asarray(_times(q, cap))
    pl = np.asarray(EQ.leaf_payloads(q))[:cap]
    rebuilt = EQ.build_tree(lt, typ=pl[:, 0], a=pl[:, 1:])
    assert np.array_equal(np.asarray(rebuilt), np.asarray(q["evq_tree"]))


def test_pop_slot_matches_argmin_under_ties():
    """The tree's root reproduces jnp.argmin's lowest-index-wins rule on
    adversarially tied inputs."""
    rng = np.random.default_rng(7)
    cap = 64
    d = EQ.tree_depth(cap)
    for _ in range(50):
        times = rng.integers(0, 3, size=cap).astype(np.float32)
        q = _from_times(cap, times)
        _, t, slot, _, _ = _jit_pop(q, d)
        assert int(slot) == int(np.argmin(times))
        assert float(t) == float(times.min())


def test_slot_assignment_matches_linear_rule():
    """The j-th masked entry takes the j-th lowest free slot — the linear
    impl's first-free-slot search — across segment boundaries."""
    cap = 256                               # spans 4 ALLOC_SEG=64 segments
    d = EQ.tree_depth(cap)
    q = _push(EQ.empty(cap), np.full(cap, 5.0, np.float32), cap=cap)
    freed = [0, 1, 63, 64, 130, 200, 255]   # free a scattered set
    for _ in range(len(freed)):
        q, _, _, _, _ = _jit_pop(q, d)      # pops are all t=5, slot order
    assert sorted(np.flatnonzero(_times(q, cap) >= INF).tolist()) \
        == list(range(7))
    # free specific scattered slots instead: rebuild that state directly
    ev = np.full(cap, 5.0, np.float32)
    ev[freed] = INF
    q = _from_times(cap, ev)
    mask = np.array([True, False, True, True, False, True, True])
    q = _push(q, np.arange(10.0, 17.0).astype(np.float32), mask=mask,
              cap=cap)
    got = {s: float(t) for s, t in enumerate(_times(q, cap))
           if t < INF and float(t) != 5.0}
    # masked entries (indices 0,2,3,5,6) land on freed slots in order
    assert got == {0: 10.0, 1: 12.0, 63: 13.0, 64: 15.0, 130: 16.0}


def test_inf_time_push_keeps_counters_in_sync():
    """A masked entry with time >= INF takes its slot in the assignment
    order (linear parity) but leaves the slot free — the segment
    counters must keep matching the INF-leaf count exactly."""
    cap = 128
    q = _push(EQ.empty(cap), [5.0, INF, 7.0], cap=cap)
    lt = _times(q, cap)
    # entry 1 consumed slot 1 in the assignment order but left it free
    assert (float(lt[0]), float(lt[2])) == (5.0, 7.0) and lt[1] >= INF
    assert np.array_equal(np.asarray(EQ.build_freecnt(lt >= INF)),
                          np.asarray(EQ.freecnt(q)))
    # the freed-looking slot is allocatable again, counters still exact
    q = _push(q, [9.0], cap=cap)
    lt = _times(q, cap)
    assert float(lt[1]) == 9.0
    assert np.array_equal(np.asarray(EQ.build_freecnt(lt >= INF)),
                          np.asarray(EQ.freecnt(q)))
    assert int(q["dropped"]) == 0


def test_overflow_drops_match_linear_accounting():
    """Excess masked entries drop exactly like the linear impl: the first
    total_free masked entries land, the tail is counted in dropped."""
    cap = 8
    q = _push(EQ.empty(cap), np.arange(1.0, 7.0).astype(np.float32),
              cap=cap)                                            # 6 in
    q = _push(q, np.arange(10.0, 15.0).astype(np.float32), cap=cap)  # 5 > 2
    assert int(q["dropped"]) == 3
    ev = _times(q, cap)
    assert float(ev[6]) == 10.0 and float(ev[7]) == 11.0
    # full queue: everything drops
    q = _push(q, np.array([99.0], np.float32), cap=cap)
    assert int(q["dropped"]) == 4


def _params(**kw):
    kw.setdefault("m", 16)
    kw.setdefault("k", 4)
    kw.setdefault("n_childs", 16)
    kw.setdefault("max_apps", 32)
    kw.setdefault("queue_cap", 512)
    return SimParams(**kw)


@pytest.mark.parametrize("topology", ["ideal", "mesh2d"])
def test_tree_vmap_equals_seq_bitwise(topology):
    """queue_impl="tree" keeps the sweep engine's bitwise vmap == seq
    contract on both the golden fabric and a non-ideal one."""
    p = _params(topology=topology, queue_impl="tree")
    wl = W.interference_batch(p, seeds=(0, 1), sim_len=2e5)
    kn = SW.knob_batch(dn_th=(2, 8))
    sv = SW.sweep(p.shape, kn, wl, 2e5, mode="vmap", topology=topology)
    ss = SW.sweep(p.shape, kn, wl, 2e5, mode="seq", topology=topology)
    for key in ("app_done", "app_arrive", "beacons_tx", "beacons_rx",
                "events_processed", "dropped"):
        assert np.array_equal(np.asarray(sv[key]), np.asarray(ss[key])), key


def test_tree_queue_state_shapes_and_cap_guard():
    qs = EQ.queue_state(512)
    assert qs["evq_tree"].shape == (2 * 512 + 512 // EQ.ALLOC_SEG, EQ.ROW_W)
    assert int(np.asarray(EQ.freecnt(qs)).sum()) == 512
    # non-power-of-two caps round up to the padded leaf count
    assert np.asarray(EQ.leaf_times(EQ.queue_state(100))).shape == (128,)
    with pytest.raises(ValueError):
        EQ.build_tree(jnp.zeros((EQ.MAX_QUEUE_CAP + 1,), jnp.float32))


def test_sim_rejects_unknown_queue_impl():
    with pytest.raises(ValueError):
        _params(queue_impl="radix")
    with pytest.raises(ValueError):
        SW.sweep(_params().shape, SW.knob_batch(dn_th=(1,)),
                 W.interference_batch(_params(), seeds=(0,), sim_len=1e5),
                 1e5, queue_impl="calendar")
