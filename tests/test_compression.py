"""int8 gradient compression: quantization error bounds + error feedback."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.parallel import compression as C


@given(st.lists(st.floats(-100, 100, allow_nan=False, width=32),
                min_size=4, max_size=64))
@settings(max_examples=50, deadline=None)
def test_quantize_error_bounded(vals):
    g = jnp.asarray(vals, jnp.float32)
    q, scale, res = C.quantize(g, jnp.zeros_like(g))
    deq = C.dequantize(q, scale)
    # per-element error bounded by half a quantization step
    assert float(jnp.abs(deq - g).max()) <= float(scale) / 2 + 1e-6
    assert float(jnp.abs(res - (g - deq)).max()) < 1e-5


def test_error_feedback_preserves_signal():
    """Repeatedly sending the same tiny gradient: with error feedback the
    accumulated transmitted mass converges to the true total."""
    g = jnp.full((8,), 1e-3)
    big = jnp.zeros((8,)).at[0].set(1.0)       # forces a coarse scale
    err = jnp.zeros((8,))
    sent = jnp.zeros((8,))
    for _ in range(100):
        q, s, err = C.quantize(g + big * 0, err)
        sent = sent + C.dequantize(q, s)
        # scale driven by big outlier in realistic trees; here self-scale
    true_total = g * 100
    assert float(jnp.abs(sent - true_total).max()) < float(g[0])  # <1 step


def test_compress_grads_tree():
    tree = {"w": jnp.asarray([1.0, -2.0, 3.0]),
            "b": {"x": jnp.asarray([[0.5, -0.5]])}}
    err = C.init_error_state(tree)
    out, err2 = C.compress_grads(tree, err)
    assert jax.tree_util.tree_structure(out) == \
        jax.tree_util.tree_structure(tree)
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(tree)):
        assert float(jnp.abs(a - b).max()) < 0.05 * float(jnp.abs(b).max())
