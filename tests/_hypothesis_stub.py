"""Deterministic micro-fallback for the ``hypothesis`` package.

Installed into ``sys.modules`` by conftest.py only when real hypothesis is
unavailable (the pinned CI environment installs the real package; see
pyproject.toml).  Implements just the API subset this test-suite uses —
``given``/``settings`` and the ``integers``/``floats``/``booleans``/
``lists``/``tuples``/``sampled_from``/``composite`` strategies — drawing
examples from a PRNG seeded from the test name, so runs are reproducible.
No shrinking, no example database: a much weaker searcher than real
hypothesis, but it keeps the property tests executable everywhere.
"""
from __future__ import annotations

import functools
import inspect
import types
import zlib

import numpy as np

DEFAULT_EXAMPLES = 20


class _Strategy:
    def __init__(self, sample):
        self._sample = sample

    def draw(self, rng):
        return self._sample(rng)


def integers(min_value=0, max_value=None):
    hi = (2 ** 31 - 1) if max_value is None else max_value
    return _Strategy(lambda rng: int(rng.integers(min_value, hi + 1)))


def floats(min_value=0.0, max_value=1.0, **_kw):
    return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


def booleans():
    return _Strategy(lambda rng: bool(rng.integers(0, 2)))


def sampled_from(elements):
    seq = list(elements)
    return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])


def lists(elements, min_size=0, max_size=10, **_kw):
    def sample(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elements.draw(rng) for _ in range(n)]
    return _Strategy(sample)


def tuples(*strategies):
    return _Strategy(lambda rng: tuple(s.draw(rng) for s in strategies))


def composite(fn):
    @functools.wraps(fn)
    def builder(*args, **kw):
        return _Strategy(lambda rng: fn(lambda s: s.draw(rng), *args, **kw))
    return builder


def settings(max_examples=DEFAULT_EXAMPLES, **_kw):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(*arg_strategies, **kw_strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(fn, "_stub_max_examples", DEFAULT_EXAMPLES)
            rng = np.random.default_rng(zlib.crc32(fn.__name__.encode()))
            for _ in range(n):
                extra = [s.draw(rng) for s in arg_strategies]
                kws = {name: s.draw(rng)
                       for name, s in kw_strategies.items()}
                fn(*args, *extra, **kwargs, **kws)

        # hide the strategy-filled parameters from pytest's fixture
        # resolution: positional strategies fill from the right, keyword
        # strategies by name — whatever remains (e.g. fixtures) stays
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        if arg_strategies:
            params = params[: len(params) - len(arg_strategies)]
        params = [q for q in params if q.name not in kw_strategies]
        wrapper.__signature__ = sig.replace(parameters=params)
        del wrapper.__wrapped__
        return wrapper
    return deco


strategies = types.ModuleType("hypothesis.strategies")
for _name, _obj in [("integers", integers), ("floats", floats),
                    ("booleans", booleans), ("sampled_from", sampled_from),
                    ("lists", lists), ("tuples", tuples),
                    ("composite", composite)]:
    setattr(strategies, _name, _obj)
