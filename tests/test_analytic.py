"""Analytic model (Eqns 1-4, Fig 2a)."""
import numpy as np

from repro.core import analytic as A


def test_omega_components():
    p = A.TimingParams()
    # k=1: no global stage, full local stage over m PEs
    assert A.omega_cmp(256, 100, 1, p.c_s) == 100 * 8 * 8
    # k=m: no local stage
    assert A.omega_cmp(256, 256, 256, p.c_s) == np.log2(256) * 8 * 8
    # message overhead is convex in k with min at k=sqrt(m)
    ks = np.array([1, 4, 16, 64, 256])
    msg = A.omega_msg(256, 100, ks, p.c_b)
    assert msg.argmin() == 2     # k=16=sqrt(256)


def test_speedup_bounded_by_ideal():
    s = A.speedup(256, 256, np.array([1, 8, 16, 64, 256]))
    ideal = 256  # n tasks on m>=n PEs
    assert np.all(s <= ideal)
    assert np.all(s > 0)


def test_fig2a_optimum_in_paper_band():
    out = A.fig2a()
    for cs, curve in out.items():
        best_k = curve["k"][int(np.argmax(curve["speedup"]))]
        if cs >= 8.0:  # paper: recursive startup favours 32-64 nodes
            assert 16 <= best_k <= 64, (cs, best_k)


def test_optimal_k_monotone_in_cs():
    """Costlier selection pushes the optimum to more clusters."""
    k_cheap = A.optimal_k(256, 256, A.TimingParams(c_s=1.0))
    k_dear = A.optimal_k(256, 256, A.TimingParams(c_s=64.0))
    assert k_dear >= k_cheap
