"""Policy-core semantics: traced/host agreement, staleness bounds, and
the design-space distinguishability of the mapping/beacon policies
(core/policies.py, DESIGN.md §9)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import beacons as B
from repro.core import policies as P
from repro.core import sweep as SW
from repro.core import workloads as W
from repro.core.sim import SimParams, run


def _params(**kw):
    kw.setdefault("m", 16)
    kw.setdefault("k", 4)
    kw.setdefault("n_childs", 16)
    kw.setdefault("max_apps", 32)
    kw.setdefault("queue_cap", 512)
    return SimParams(**kw)


# --------------------------------------------------------------------------
# Threshold beacon policy: staleness bound (paper Sec 4.2 / Sec 6)
# --------------------------------------------------------------------------

@given(st.lists(st.tuples(st.integers(0, 2), st.integers(0, 100)),
                min_size=1, max_size=80),
       st.integers(1, 16))
@settings(max_examples=40, deadline=None)
def test_threshold_bounds_staleness_by_dn_th_minus_1(updates, dn_th):
    """After a node reports, every remote view of it is within dn_th - 1
    of the reported load: drift >= dn_th forces a broadcast, so the error
    a remote can carry is at most dn_th - 1."""
    s = B.BeaconState.create(k=3, dn_th=dn_th)
    true = np.zeros(3, np.int64)
    for node, load in updates:
        s = B.update(s, node, load)
        true[node] = load
        err = np.abs(s.view - true[None, :])
        off_diag = ~np.eye(3, dtype=bool)
        assert err[off_diag].max() <= dn_th - 1
    assert B.staleness(s, true) <= dn_th - 1


def test_periodic_and_hybrid_beacon_state_machine():
    s = B.BeaconState.create(k=2, dn_th=10**9, policy="periodic", T_b=10.0)
    s = B.update(s, 0, 50, now=5.0)
    assert s.tx_count == 0                    # deadline not reached
    s = B.update(s, 0, 51, now=10.0)
    assert s.tx_count == 1                    # fired on deadline, not drift
    h = B.BeaconState.create(k=2, dn_th=4, policy="hybrid", T_b=100.0)
    h = B.update(h, 0, 4, now=1.0)
    assert h.tx_count == 1                    # drift arm fires early


# --------------------------------------------------------------------------
# Traced vs host adapters: one logic, two domains
# --------------------------------------------------------------------------

def test_hash_traced_matches_host():
    for a, b, c in [(0, 0, 0), (1, 2, 3), (123456, 7, 89), (2**31 - 1, 5, 9)]:
        traced = int(P._hash_u32(jnp.asarray(a), jnp.asarray(b),
                                 jnp.asarray(c)))
        assert traced == P._hash_u32_host(a, b, c)


@pytest.mark.parametrize("name", P.MAPPING_POLICIES)
def test_host_pick_matches_traced(name):
    rng = np.random.default_rng(0)
    fn = P.mapping_policy(name)
    for trial in range(16):
        k = int(rng.integers(2, 9))
        view = rng.integers(0, 6, k)
        age = rng.uniform(0, 5000, k)
        g = int(rng.integers(0, k))
        age[g] = 0.0
        rr, app, i = (int(rng.integers(0, 50)) for _ in range(3))
        traced = int(fn(jnp.asarray(view), jnp.asarray(age, jnp.float32),
                        jnp.asarray(g), jnp.asarray(rr), jnp.asarray(app),
                        jnp.asarray(i), k=k, T_b=jnp.float32(1000.0)))
        host = P.host_pick(name, view, age, g, rr, app, i, T_b=1000.0)
        assert traced == host, (name, trial)


def test_host_stage2_masks_dead_units():
    assert P.host_stage2([3.0, 1.0, 2.0]) == 1
    assert P.host_stage2([3.0, 1.0, 2.0], alive=[True, False, True]) == 2


def test_unknown_policy_names_raise():
    with pytest.raises(ValueError):
        P.SimPolicy(mapping="nope")
    with pytest.raises(ValueError):
        P.SimPolicy(beacon="nope")
    with pytest.raises(ValueError):
        P.host_pick("nope", np.zeros(2))
    with pytest.raises(ValueError):
        P.host_beacon_due("nope", 1, dn_th=1)


# --------------------------------------------------------------------------
# Simulator-level policy semantics
# --------------------------------------------------------------------------

def test_min_search_vs_round_robin_identical_when_views_uniform():
    """With a single application the deciding GMN's view is uniform (all
    zeros) for the whole fork expansion, and min_search's own-index-first
    tie-break walks clusters in exactly round_robin's order — the two
    policies are bitwise indistinguishable."""
    wl = W.independent_tasks(_params(), n_apps=1)
    d1 = run(_params(), *wl, 1e7)
    d2 = run(_params(mapping="round_robin"), *wl, 1e7)
    assert np.array_equal(np.asarray(d1["app_done"]),
                          np.asarray(d2["app_done"]))
    assert np.array_equal(np.asarray(d1["beacons_tx"]),
                          np.asarray(d2["beacons_tx"]))


def test_min_search_vs_round_robin_differ_when_views_differ():
    """Under interference with a coarse threshold the views diverge (own
    column exact, remote columns stale) and the view-driven policy makes
    different decisions.  (At dn_th=1 the views stay so fresh and the
    saturated clusters so equalized that the two policies still coincide
    — distinguishability requires differing views, not just load.)"""
    p = _params(dn_th=4)
    wl = W.interference(p, sim_len=3e5, seed=0)
    s1 = run(p, *wl, 3e5)
    s2 = run(_params(dn_th=4, mapping="round_robin"), *wl, 3e5)
    same_done = np.array_equal(np.asarray(s1["app_done"]),
                               np.asarray(s2["app_done"]))
    same_btx = int(s1["beacons_tx"]) == int(s2["beacons_tx"])
    assert not (same_done and same_btx)


def test_hybrid_with_unreachable_deadline_equals_threshold_bitwise():
    p_th = _params(dn_th=4, T_b=1e9)
    p_hy = _params(dn_th=4, T_b=1e9, beacon="hybrid")
    wl = W.interference(p_th, sim_len=3e5, seed=1)
    s1, s2 = run(p_th, *wl, 3e5), run(p_hy, *wl, 3e5)
    assert int(s1["beacons_tx"]) == int(s2["beacons_tx"])
    assert np.array_equal(np.asarray(s1["app_done"]),
                          np.asarray(s2["app_done"]))


def test_periodic_beacon_decoupled_from_drift():
    """periodic fires on the T_b deadline even when the threshold arm
    would stay silent, and stays silent when the deadline is unreachable."""
    wl = W.interference(_params(), sim_len=3e5, seed=0)
    silent = run(_params(dn_th=10**6, beacon="periodic", T_b=1e9), *wl, 3e5)
    assert int(silent["beacons_tx"]) == 0
    chatty = run(_params(dn_th=10**6, beacon="periodic", T_b=500.0),
                 *wl, 3e5)
    assert int(chatty["beacons_tx"]) > 0


def test_policy_grid_runs_through_sweep():
    """>= 3 mapping x 3 beacon combinations end-to-end through sweep():
    every combo completes all apps without event-queue drops."""
    p = _params()
    wl = W.interference_batch(p, seeds=(0,), sim_len=2e5)
    knobs = SW.knob_batch(dn_th=(2, 8), T_b=1000.0)
    mappings = ("min_search", "round_robin", "staleness_weighted")
    out = SW.sweep_policies(p.shape, knobs, wl,
                            SW.policy_grid(mappings, P.BEACON_POLICIES),
                            sim_len=2e5)
    assert len(out) == 9
    for key, st_ in out.items():
        assert np.asarray(st_["dropped"]).sum() == 0, key
        assert np.isfinite(SW.mean_response(st_)).all(), key
    # the beacon axis really is live: periodic != threshold traffic
    b_th = SW.beacons(out[("min_search", "threshold")])
    b_pe = SW.beacons(out[("min_search", "periodic")])
    assert not np.array_equal(b_th, b_pe)


# --------------------------------------------------------------------------
# Serving engine rides the same policies (wall-clock adapter)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("mapping", P.MAPPING_POLICIES)
def test_fleet_completes_under_every_mapping_policy(mapping):
    from repro.serving.engine import FleetSim, Request
    t_b = 50.0 if mapping == "staleness_weighted" else float("inf")
    fleet = FleetSim(k=4, groups_per_cluster=2, dn_th=2, mapping=mapping,
                     T_b=t_b)
    for i in range(32):
        fleet.submit(Request(sort_key=float(i), rid=i, max_new=8))
    for _ in range(300):
        if not fleet.active:
            break
        fleet.tick()
    assert len(fleet.finished) == 32
    if mapping in ("min_search", "round_robin"):
        per_cluster = fleet.loads().sum(axis=1)
        assert per_cluster.max() - per_cluster.min() < 1e-9


def test_fleet_staleness_weighted_requires_finite_T_b():
    from repro.serving.engine import FleetSim
    with pytest.raises(ValueError, match="finite T_b"):
        FleetSim(k=2, groups_per_cluster=2, dn_th=2,
                 mapping="staleness_weighted")


def test_fleet_periodic_beacons_fire_on_wall_clock():
    from repro.serving.engine import FleetSim, Request
    fleet = FleetSim(k=2, groups_per_cluster=2, dn_th=10**9,
                     beacon="periodic", T_b=5.0)
    fleet.submit(Request(sort_key=0.0, rid=0, max_new=10**6))
    assert fleet.beacons_tx == 0
    for _ in range(20):
        fleet.tick()
    assert fleet.beacons_tx > 0


def test_fleet_drained_cluster_still_broadcasts():
    """A cluster whose last request finished must still get its beacon
    polled: under periodic/hybrid policies the load drop would otherwise
    never reach remote views and the idle cluster would look busy forever."""
    from repro.serving.engine import FleetSim, Request
    fleet = FleetSim(k=2, groups_per_cluster=1, dn_th=10**9,
                     beacon="periodic", T_b=3.0)
    fleet.submit(Request(sort_key=0.0, rid=0, max_new=8), via_cluster=0)
    while fleet.active:
        fleet.tick()
    for _ in range(10):
        fleet.tick()                       # no active keys left anywhere
    assert fleet.beacons_tx > 0
    # remote views converged to the true (zero) load
    assert fleet.schedulers[1].remote[fleet.finished[0].cluster] == 0.0
