"""Interconnect transport subsystem (core/transport.py, DESIGN.md §10):
topology validation, mesh geometry, delivery-time monotonicity in hop
count, beacon conservation across the (k, k) in-flight matrix,
per-receiver heterogeneity, and the shared_bus >= hier_tree contention
property."""
import numpy as np
import pytest

from repro.core import transport as T
from repro.core import workloads as W
from repro.core.sim import SimParams, run


def _params(topology, k=4, **kw):
    kw.setdefault("m", 16)
    kw.setdefault("n_childs", 16)
    kw.setdefault("max_apps", 32)
    kw.setdefault("queue_cap", 512)
    return SimParams(k=k, topology=topology, **kw)


NON_IDEAL = tuple(t for t in T.TOPOLOGIES if t != "ideal")


# -- static geometry --------------------------------------------------------

def test_topology_validation():
    assert T.Topology().kind == "ideal"
    with pytest.raises(ValueError):
        T.Topology("torus")
    with pytest.raises(ValueError):
        _params("nonsense").topo   # validated like mapping/beacon: on use
    assert [t.kind for t in T.topology_grid()] == list(T.TOPOLOGIES)


@pytest.mark.parametrize("k", [1, 2, 4, 9, 16, 30])
def test_mesh_hops_geometry(k):
    h = T.mesh_hops(k)
    assert h.shape == (k, k)
    assert (h == h.T).all(), "hop counts must be symmetric"
    assert (np.diag(h) == 0).all()
    if k > 1:
        off = h[~np.eye(k, dtype=bool)]
        assert (off >= 1).all()
        side = T.grid_side(k)
        assert off.max() <= 2 * (side - 1)


def test_mesh_delivery_monotone_in_hops():
    """mesh2d delivery time grows monotonically with Manhattan distance
    (idle fabric: arrival = injection + hops * c_hop exactly)."""
    import jax.numpy as jnp
    k = 16
    topo = T.Topology("mesh2d")
    hops = jnp.asarray(T.mesh_hops(k))
    lbus = jnp.zeros((k,))
    arrs = []
    for dst in range(1, k):
        t_arr, _, _, lat = T.unicast(
            topo, jnp.int32(0), jnp.int32(dst), jnp.float32(100.0),
            jnp.bool_(True), gbus=jnp.float32(0.0), lbus=lbus,
            c_b=jnp.float32(8.0), c_hop=jnp.float32(2.0), hops=hops)
        arrs.append((int(T.mesh_hops(k)[0, dst]), float(t_arr)))
        assert float(lat) == float(t_arr) - 100.0
    arrs.sort()
    times = [t for _, t in arrs]
    assert all(a <= b for a, b in zip(times, times[1:])), \
        "delivery must be monotone in hop count"
    # exactly injection (108) + hops * 2
    for h, t in arrs:
        assert t == 108.0 + 2.0 * h


def test_host_beacon_delays_shapes_and_monotonicity():
    for kind in T.TOPOLOGIES:
        d = T.host_beacon_delays(kind, 9, src=2, c_b=1.0, c_hop=0.5)
        assert d.shape == (9,)
        assert d[2] == 0.0
        if kind == "ideal":
            assert (d == 0).all()
        else:
            assert (np.delete(d, 2) > 0).all()
    # mesh: delay ordered by hop count
    d = T.host_beacon_delays("mesh2d", 16, src=0, c_b=1.0, c_hop=0.5)
    h = T.mesh_hops(16)[0]
    order = np.argsort(h[1:]) + 1
    assert (np.diff(d[order]) >= 0).all()
    with pytest.raises(ValueError):
        T.host_beacon_delays("bogus", 4, 0)


# -- conservation across the (k, k) in-flight matrix ------------------------

@pytest.mark.parametrize("topology", NON_IDEAL)
@pytest.mark.parametrize("seed", [0, 1])
def test_beacon_conservation(topology, seed):
    """No beacon is lost or duplicated: every fired beacon produces
    exactly k-1 per-receiver deliveries, and the in-flight matrix drains
    by the end of the run."""
    p = _params(topology)
    wl = W.interference(p, sim_len=3e5, seed=seed)
    st = run(p, *wl, 3e5)
    tx = int(st["beacons_tx"])
    rx = int(st["beacons_rx"])
    assert tx > 0, "workload must actually fire beacons"
    assert rx == (p.k - 1) * tx, \
        f"conservation violated: rx={rx} tx={tx}"
    assert (np.asarray(st["bcn_t"]) >= 1e17).all(), \
        "in-flight matrix must drain"
    assert int(st["dropped"]) == 0


def test_ideal_has_no_transport_traffic():
    """Under the ideal fabric the in-flight machinery stays untouched:
    no BEACON_RX deliveries, no skew, matrix empty."""
    p = _params("ideal")
    wl = W.interference(p, sim_len=3e5, seed=0)
    st = run(p, *wl, 3e5)
    assert int(st["beacons_tx"]) > 0
    assert int(st["beacons_rx"]) == 0
    assert float(st["bcn_skew_max"]) == 0.0
    assert (np.asarray(st["bcn_t"]) >= 1e17).all()


# -- per-receiver heterogeneity ---------------------------------------------

@pytest.mark.parametrize("topology", NON_IDEAL)
def test_beacon_skew_positive(topology):
    """Non-ideal fabrics deliver one beacon at different times to
    different receivers (max - min arrival spread > 0 at least once),
    which is exactly the per-receiver age heterogeneity of
    deviation §8.2."""
    p = _params(topology, k=4)
    wl = W.interference(p, sim_len=3e5, seed=0)
    st = run(p, *wl, 3e5)
    assert float(st["bcn_skew_max"]) > 0.0
    assert float(st["bcn_skew_sum"]) > 0.0


@pytest.mark.parametrize("topology", ["shared_bus", "mesh2d"])
def test_view_timestamps_heterogeneous(topology):
    """Receivers' view_t columns differ for the same source under
    fabrics with structurally distinct per-receiver paths."""
    p = _params(topology, k=4)
    wl = W.interference(p, sim_len=3e5, seed=0)
    st = run(p, *wl, 3e5)
    vt = np.asarray(st["view_t"])
    hetero = False
    for src in range(p.k):
        col = [vt[g, src] for g in range(p.k) if g != src and vt[g, src] > 0]
        if len(set(np.round(col, 6))) > 1:
            hetero = True
    assert hetero, f"no heterogeneous view_t column under {topology}"


# -- contention ordering ----------------------------------------------------

def test_shared_bus_beacon_messages_geq_hier_tree_under_contention():
    """Per fired beacon the flat bus carries k-1 serialized beacon
    messages on its single contended medium, where the hierarchical
    fabric's contended global bus carries exactly one grant (deliveries
    fan out over the per-cluster local buses).  Under a contended
    workload the count of beacon messages crossing the shared medium
    therefore dominates hier_tree's global-bus beacon count.  (The
    *transmission* counts themselves are not ordered: the threshold
    trigger reacts to each GMN's own load drift, which feeds back
    through mapping decisions chaotically.)"""
    for seed in (0, 1):
        msgs = {}
        for topology in ("shared_bus", "hier_tree"):
            p = _params(topology, k=4, m=16, n_childs=16)
            wl = W.interference(p, sim_len=3e5, pair_period=7_000.0,
                                seed=seed)
            st = run(p, *wl, 3e5)
            tx = int(st["beacons_tx"])
            assert tx > 0 and int(st["dropped"]) == 0
            # beacon messages on the fabric's contended shared medium
            if topology == "shared_bus":
                msgs[topology] = int(st["beacons_rx"])   # == (k-1) * tx
                assert msgs[topology] == (p.k - 1) * tx
            else:
                msgs[topology] = tx                      # one global grant
        assert msgs["shared_bus"] >= msgs["hier_tree"], msgs


def test_shared_bus_comm_latency_exceeds_hier_tree():
    """Same messages, one contended medium: the shared bus pays strictly
    more transport latency than the two-level fabric under load."""
    lat = {}
    for topology in ("shared_bus", "hier_tree"):
        p = _params(topology, k=4, m=16, n_childs=16)
        wl = W.interference(p, sim_len=3e5, pair_period=7_000.0, seed=0)
        st = run(p, *wl, 3e5)
        lat[topology] = float(st["mgmt_latency"])
    assert lat["shared_bus"] > lat["hier_tree"], lat


def test_vmap_seq_bitwise_equal_under_mesh2d():
    """The BEACON_RX branch batches correctly: both sweep execution modes
    produce bitwise-identical results on a non-ideal fabric (the vmapped
    lax.switch executes every handler each step with masked selects)."""
    from repro.core import sweep as SW
    p = SimParams(m=8, k=4, n_childs=8, max_apps=16, queue_cap=256,
                  topology="mesh2d")
    wl = W.interference_batch(p, seeds=(0,), sim_len=1e5)
    kn = SW.knob_batch(dn_th=(2, 8))
    a = SW.sweep(p.shape, kn, wl, 1e5, mode="seq", topology="mesh2d")
    b = SW.sweep(p.shape, kn, wl, 1e5, mode="vmap", topology="mesh2d")
    for key in ("app_done", "beacons_tx", "beacons_rx", "bcn_skew_sum",
                "mgmt_latency", "bcn_t"):
        assert np.array_equal(np.asarray(a[key]), np.asarray(b[key])), key


# -- applications still complete on every fabric ----------------------------

@pytest.mark.parametrize("topology", T.TOPOLOGIES)
def test_apps_complete_on_every_topology(topology):
    p = _params(topology)
    wl = W.interference(p, sim_len=3e5, seed=0)
    st = run(p, *wl, 3e5)
    done = np.asarray(st["app_done"])
    arr = np.asarray(st["app_arrive"])
    started = (arr < 1e17).sum()
    assert started > 0
    assert (done < 1e17).sum() == started, "every started app must finish"
    assert int(st["dropped"]) == 0
    # a slower fabric never finishes an app earlier than... is not a
    # theorem (mapping decisions change); but responses must be sane
    ok = done < 1e17
    assert (done[ok] >= arr[ok]).all()
