"""Sharding rules: every sharded dim divides its mesh axes, for all 10
architectures on both production meshes — no compilation needed.

Runs in a subprocess with 512 placeholder devices (XLA_FLAGS must be set
before jax initializes, which pytest's process already did with 1 device),
so here we validate divisibility arithmetically against mesh SHAPES.
"""
import functools

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.launch.steps import abstract_params, abstract_cache, input_specs
from repro.configs.base import SHAPES, shape_supported
from repro.parallel import sharding as SH


class FakeMesh:
    """Mesh stand-in exposing .shape/.axis_names (no devices needed)."""

    def __init__(self, multi_pod):
        self.axis_names = (("pod", "data", "model") if multi_pod
                           else ("data", "model"))
        self.shape = dict(zip(self.axis_names,
                              (2, 16, 16) if multi_pod else (16, 16)))


def _axis_prod(mesh, entry):
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        p = 1
        for e in entry:
            p *= mesh.shape[e]
        return p
    return mesh.shape[entry]


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("multi_pod", [False, True])
def test_param_spec_divisibility(arch, multi_pod):
    cfg = get_config(arch)
    mesh = FakeMesh(multi_pod)
    params = abstract_params(cfg, jnp.bfloat16)
    flat, _ = SH._tree_paths(params)
    dp_ax = ("pod", "data") if multi_pod else ("data",)
    dp = 1
    for a in dp_ax:
        dp *= mesh.shape[a]
    for path, leaf in flat:
        spec = SH.param_spec(cfg, mesh, path, leaf.shape)
        spec = SH._add_fsdp(spec, leaf.shape, dp_ax, dp)
        entries = list(spec) + [None] * (len(leaf.shape) - len(spec))
        for dim, entry in zip(leaf.shape, entries):
            assert dim % _axis_prod(mesh, entry) == 0, \
                (arch, path, leaf.shape, spec)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape_name", ["decode_32k", "long_500k"])
def test_cache_spec_divisibility(arch, shape_name):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if not shape_supported(cfg, shape):
        pytest.skip("unsupported long-context arch")
    mesh = FakeMesh(False)
    cache = abstract_cache(cfg, shape, jnp.bfloat16)
    flat, _ = SH._tree_paths(cache)
    for path, leaf in flat:
        spec = SH.cache_spec(cfg, mesh, path, leaf.shape,
                             batch=shape.global_batch)
        entries = list(spec) + [None] * (len(leaf.shape) - len(spec))
        for dim, entry in zip(leaf.shape, entries):
            assert dim % _axis_prod(mesh, entry) == 0, \
                (arch, path, leaf.shape, spec)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_complete(arch):
    cfg = get_config(arch)
    for shape in SHAPES.values():
        if not shape_supported(cfg, shape):
            continue
        spec = input_specs(cfg, shape)
        if shape.kind in ("train", "prefill"):
            assert "tokens" in spec
            if cfg.frontend == "vision":
                assert "patches" in spec
                assert (spec["tokens"].shape[1] + cfg.vision_tokens
                        == shape.seq_len)
            if cfg.family == "encdec":
                assert "frames" in spec
        else:
            assert spec["token"].shape == (shape.global_batch, 1)


def test_fsdp_picks_large_free_dim():
    from jax.sharding import PartitionSpec as P
    spec = SH._add_fsdp(P(None, "model"), (8192, 1024), ("data",), 16)
    assert spec == P("data", "model")
    # too small / non-divisible dims stay unsharded
    spec = SH._add_fsdp(P(None,), (100,), ("data",), 16)
    assert spec == P(None,)
