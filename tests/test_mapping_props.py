"""Property-based tests (hypothesis) for the two-stage mapper."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import mapping as M
from repro.kernels import ref


@st.composite
def load_matrix(draw):
    k = draw(st.sampled_from([1, 2, 4, 8]))
    mpk = draw(st.sampled_from([1, 2, 4, 8]))
    # subnormals excluded: XLA flushes them to zero (FTZ) while numpy keeps
    # them, so argmin ties resolve differently — not a scheduler bug
    vals = draw(st.lists(st.floats(0, 100, allow_nan=False, width=32,
                                   allow_subnormal=False),
                         min_size=k * mpk, max_size=k * mpk))
    return np.array(vals, np.float32).reshape(k, mpk)


@given(load_matrix())
@settings(max_examples=50, deadline=None)
def test_minsearch_picks_global_min_cluster(loads):
    c, p = ref.hier_minsearch_ref(jnp.asarray(loads))
    sums = loads.sum(axis=1)
    assert sums[int(c)] == sums.min()
    assert loads[int(c), int(p)] == loads[int(c)].min()


@given(load_matrix(), st.integers(1, 32))
@settings(max_examples=30, deadline=None)
def test_assign_preserves_mass(loads, n_tasks):
    costs = jnp.ones((n_tasks,), jnp.float32)
    assigns, new_loads = ref.assign_tasks_ref(jnp.asarray(loads), costs)
    assert np.isclose(float(new_loads.sum()),
                      float(loads.sum()) + n_tasks, atol=1e-3)
    a = np.asarray(assigns)
    assert (a[:, 0] >= 0).all() and (a[:, 0] < loads.shape[0]).all()
    assert (a[:, 1] >= 0).all() and (a[:, 1] < loads.shape[1]).all()


@given(st.integers(1, 8), st.integers(1, 8), st.integers(1, 64))
@settings(max_examples=30, deadline=None)
def test_uniform_costs_balance(k, mpk, n_tasks):
    """Mapping equal tasks onto empty clusters ends within 1 of balanced."""
    loads = jnp.zeros((k, mpk), jnp.float32)
    _, new_loads = ref.assign_tasks_ref(loads, jnp.ones((n_tasks,)))
    nl = np.asarray(new_loads)
    assert nl.max() - nl.min() <= 1.0 + 1e-6


@given(st.integers(2, 64), st.integers(1, 16))
@settings(max_examples=20, deadline=None)
def test_fork_tree_targets_bounds(n_tasks, k):
    mpk = 4
    ns, depth = M.fork_tree_targets(n_tasks, k, mpk)
    assert 1 <= ns <= k
    assert ns >= min(k, -(-n_tasks // mpk))  # enough targets for capacity
    assert 2 ** depth >= ns
