"""Pallas selective scan + chunked XLA scan vs the naive oracle."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref
from repro.kernels.selective_scan import selective_scan

CASES = [
    # (B, S, Di, N, chunk, block_d)
    (2, 64, 16, 4, 16, 8),
    (1, 128, 32, 8, 32, 16),
    (2, 32, 8, 4, 32, 8),
    (1, 64, 8, 16, 8, 8),
]


def _inputs(B, S, Di, N, dtype=jnp.float32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (B, S, Di), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, Di), dtype) - 1)
    A = -jnp.exp(jax.random.normal(ks[2], (Di, N)) * 0.5)
    Bc = jax.random.normal(ks[3], (B, S, N), dtype)
    Cc = jax.random.normal(ks[4], (B, S, N), dtype)
    D = jnp.ones((Di,))
    return x, dt, A, Bc, Cc, D


@pytest.mark.parametrize("case", CASES)
def test_pallas_scan_vs_ref(case):
    B, S, Di, N, chunk, bd = case
    args = _inputs(B, S, Di, N)
    want = ref.selective_scan_ref(*args)
    got = selective_scan(*args, chunk=chunk, block_d=bd, interpret=True)
    assert jnp.abs(got - want).max() < 1e-4


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_chunked_scan_vs_ref(case, dtype):
    B, S, Di, N, chunk, _ = case
    args = _inputs(B, S, Di, N, dtype)
    want = ref.selective_scan_ref(*args)
    got = ops._chunked_selective_scan(*args, chunk=chunk)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    assert jnp.abs(got.astype(jnp.float32)
                   - want.astype(jnp.float32)).max() < tol


def test_chunked_scan_grad_matches_ref():
    args = _inputs(1, 32, 8, 4)

    def loss_chunked(x, dt):
        return ops._chunked_selective_scan(x, dt, *args[2:], chunk=8).sum()

    def loss_ref(x, dt):
        return ref.selective_scan_ref(x, dt, *args[2:]).sum()

    g1 = jax.grad(loss_chunked, argnums=(0, 1))(*args[:2])
    g2 = jax.grad(loss_ref, argnums=(0, 1))(*args[:2])
    for a, b in zip(g1, g2):
        assert jnp.abs(a - b).max() < 1e-3


def test_decode_step_matches_scan_tail():
    """Running the scan one step at a time reproduces the full scan."""
    B, S, Di, N = 1, 16, 8, 4
    x, dt, A, Bc, Cc, D = _inputs(B, S, Di, N)
    full = ref.selective_scan_ref(x, dt, A, Bc, Cc, D)
    h = jnp.zeros((B, Di, N))
    for t in range(S):
        h, y = ref.ssm_decode_ref(h, x[:, t], dt[:, t], A, Bc[:, t],
                                  Cc[:, t], D)
    assert jnp.abs(y - full[:, -1]).max() < 1e-4
