"""Batched sweep engine: exactness vs per-config runs, compile caching,
the beacon-threshold monotonicity property (paper Fig 3b), and the
frozen pre-policy-refactor golden outputs (PR 2 bitwise gate)."""
import hashlib

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import sweep as SW
from repro.core import workloads as W
from repro.core.sim import SimParams, SimPolicy, run


def _params(k=4, **kw):
    kw.setdefault("m", 16)
    kw.setdefault("n_childs", 16)
    kw.setdefault("max_apps", 32)
    kw.setdefault("queue_cap", 512)
    return SimParams(k=k, **kw)


THRESHOLDS = (1, 2, 4, 8)


@pytest.mark.parametrize("mode", ["vmap", "seq"])
def test_sweep_matches_per_config_bitwise(mode):
    """A batched threshold sweep must be bitwise identical to per-config
    run() calls in BOTH execution modes — vmap batches the same
    computation, it doesn't change it."""
    p = _params()
    wl = W.interference_batch(p, seeds=(0, 1), sim_len=3e5)
    stb = SW.sweep(p.shape, SW.knob_batch(dn_th=THRESHOLDS), wl, 3e5,
                   mode=mode)
    for i, th in enumerate(THRESHOLDS):
        for s in range(2):
            pi = _params(dn_th=th)
            sti = run(pi, wl[0][s], wl[1][s], wl[2][s], 3e5)
            assert np.array_equal(np.asarray(stb["beacons_tx"])[i, s],
                                  np.asarray(sti["beacons_tx"]))
            assert np.array_equal(np.asarray(stb["app_done"])[i, s],
                                  np.asarray(sti["app_done"]))
            assert np.array_equal(np.asarray(stb["app_arrive"])[i, s],
                                  np.asarray(sti["app_arrive"]))


def test_cost_knob_sweep_matches_per_config():
    """Sweeping the traced cost knobs (c_s, c_b) also reproduces the
    per-config results exactly."""
    p = _params()
    wl = W.independent_batch(p, seeds=(0,), n_apps=2)
    knobs = SW.knob_batch(c_s=(1.0, 8.0, 64.0), c_b=(2.0, 8.0, 32.0))
    stb = SW.sweep(p.shape, knobs, wl, 1e7)
    for i, (cs, cb) in enumerate(zip((1.0, 8.0, 64.0), (2.0, 8.0, 32.0))):
        sti = run(_params(c_s=cs, c_b=cb), wl[0][0], wl[1][0], wl[2][0], 1e7)
        assert np.array_equal(np.asarray(stb["app_done"])[i, 0],
                              np.asarray(sti["app_done"]))


def test_run_does_not_recompile_on_knob_change():
    """dn_th / c_* are traced: changing them re-uses the XLA program."""
    from repro.core.sim import compile_cache_size
    p = _params(m=8, k=2, n_childs=4, max_apps=8, queue_cap=128)
    arr, gmns, lens = W.independent_tasks(p, n_apps=1)
    run(p, arr, gmns, lens, 1e7)
    c0 = compile_cache_size()
    for th, cs in ((1, 2.0), (7, 16.0), (3, 1.0)):
        run(_params(m=8, k=2, n_childs=4, max_apps=8, queue_cap=128,
                    dn_th=th, c_s=cs), arr, gmns, lens, 1e7)
    assert compile_cache_size() == c0


def test_sweep_compiles_once_per_shape():
    p = _params(m=8, k=2, n_childs=4, max_apps=8, queue_cap=128)
    wl = W.independent_batch(p, seeds=(0,), n_apps=1)
    SW.sweep(p.shape, SW.knob_batch(dn_th=(1, 2)), wl, 1e7)
    c0 = SW.cache_size()
    SW.sweep(p.shape, SW.knob_batch(dn_th=(4, 16)), wl, 1e7)
    SW.sweep(p.shape, SW.knob_batch(dn_th=(3, 5), c_s=2.0), wl, 1e7)
    assert SW.cache_size() == c0


def test_knob_batch_validation():
    kn = SW.knob_batch(dn_th=(1, 2, 4))
    assert kn.dn_th.shape == (3,) and kn.c_b.shape == (3,)
    with pytest.raises(ValueError):
        SW.knob_batch(dn_th=(1, 2), c_s=(1.0, 2.0, 3.0))
    prod = SW.knob_product(c_s=(1.0, 8.0), dn_th=(1, 2, 4))
    assert prod.dn_th.shape == (6,)
    assert np.asarray(prod.c_s).tolist() == [1.0] * 3 + [8.0] * 3


# Golden outputs captured from the pre-policy-refactor implementation
# (inlined min_search + threshold logic, commit 0872ddc) on this exact
# grid: the default policy pair must keep reproducing them bitwise.
_GOLDEN_BEACONS = [[600, 600], [351, 360], [202, 232], [72, 78]]
_GOLDEN_APP_DONE_SHA = \
    "72576e858be248d11e21055618ff6a1aba89ebd7f7f4ea3419d9384b59cd3efa"


def test_default_policy_matches_pre_refactor_golden():
    """The pluggable-policy refactor must be invisible under the default
    (min_search, threshold) pair: beacons_tx and app_done over a
    (dn_th x seed) grid equal the frozen pre-refactor values bitwise."""
    p = _params()
    wl = W.interference_batch(p, seeds=(0, 1), sim_len=3e5)
    stb = SW.sweep(p.shape, SW.knob_batch(dn_th=THRESHOLDS), wl, 3e5)
    assert np.asarray(stb["beacons_tx"]).tolist() == _GOLDEN_BEACONS
    done = np.asarray(stb["app_done"], np.float32)
    assert hashlib.sha256(done.tobytes()).hexdigest() == _GOLDEN_APP_DONE_SHA
    # single-app anchor from the same capture
    st1 = run(p, *W.independent_tasks(p, n_apps=1), 1e7)
    assert float(np.asarray(st1["app_done"])[0]) == 16240.0
    assert int(st1["beacons_tx"]) == 8


@pytest.mark.parametrize("mapping,beacon", [
    ("round_robin", "periodic"), ("staleness_weighted", "hybrid")])
def test_policy_sweep_matches_per_config(mapping, beacon):
    """Non-default policy pairs obey the same sweep-vs-run exactness
    contract as the default pair."""
    p = _params(mapping=mapping, beacon=beacon, T_b=700.0)
    wl = W.interference_batch(p, seeds=(0,), sim_len=2e5)
    stb = SW.sweep(p.shape, SW.knob_batch(dn_th=(2, 8), T_b=700.0), wl, 2e5,
                   policy=SimPolicy(mapping, beacon))
    for i, th in enumerate((2, 8)):
        sti = run(_params(mapping=mapping, beacon=beacon, T_b=700.0,
                          dn_th=th), wl[0][0], wl[1][0], wl[2][0], 2e5)
        assert np.array_equal(np.asarray(stb["beacons_tx"])[i, 0],
                              np.asarray(sti["beacons_tx"]))
        assert np.array_equal(np.asarray(stb["app_done"])[i, 0],
                              np.asarray(sti["app_done"]))


def test_explicit_ideal_topology_matches_golden():
    """transport="ideal" must reproduce the pre-transport results
    bitwise: the same frozen golden grid as above, with the topology
    passed explicitly (both as a string and via sweep_topologies)."""
    p = _params()
    wl = W.interference_batch(p, seeds=(0, 1), sim_len=3e5)
    kn = SW.knob_batch(dn_th=THRESHOLDS)
    sti = SW.sweep(p.shape, kn, wl, 3e5, topology="ideal")
    assert np.asarray(sti["beacons_tx"]).tolist() == _GOLDEN_BEACONS
    done = np.asarray(sti["app_done"], np.float32)
    assert hashlib.sha256(done.tobytes()).hexdigest() == _GOLDEN_APP_DONE_SHA
    by_topo = SW.sweep_topologies(p.shape, kn, wl, topologies=("ideal",),
                                  sim_len=3e5)
    assert np.array_equal(np.asarray(by_topo["ideal"]["app_done"]), done)
    assert np.asarray(by_topo["ideal"]["beacons_tx"]).tolist() \
        == _GOLDEN_BEACONS


# fig3b-grid spot check: the benchmark's threshold row at reduced scale
# (m=64, k=16, n_childs=50, 6 thresholds, one seed), captured on commit
# 137008a immediately before the transport subsystem landed.
_FIG3B_SPOT_BEACONS = [[7178], [4254], [2224], [766], [297], [144]]
_FIG3B_SPOT_SHA = \
    "aabc517cabec6be6779f643aad59e0294c19eb29d2799a0eb8484beb88ab1cf2"


def test_fig3b_grid_spot_check_ideal_bitwise():
    p = SimParams(m=64, k=16, n_childs=50, max_apps=128, queue_cap=2048)
    wl = W.interference_batch(p, seeds=(1,), sim_len=1e6)
    st_ = SW.sweep(p.shape, SW.knob_batch(dn_th=(1, 2, 4, 8, 16, 32)),
                   wl, 1e6)
    assert np.asarray(st_["beacons_tx"]).tolist() == _FIG3B_SPOT_BEACONS
    done = np.asarray(st_["app_done"], np.float32)
    assert hashlib.sha256(done.tobytes()).hexdigest() == _FIG3B_SPOT_SHA


def test_topology_sweep_matches_per_config():
    """Non-ideal topologies obey the same sweep-vs-run exactness
    contract as the default fabric."""
    from repro.core.sim import run as sim_run
    p = _params(topology="mesh2d")
    wl = W.interference_batch(p, seeds=(0,), sim_len=2e5)
    stb = SW.sweep(p.shape, SW.knob_batch(dn_th=(2, 8)), wl, 2e5,
                   topology="mesh2d")
    for i, th in enumerate((2, 8)):
        sti = sim_run(_params(topology="mesh2d", dn_th=th),
                      wl[0][0], wl[1][0], wl[2][0], 2e5)
        assert np.array_equal(np.asarray(stb["beacons_tx"])[i, 0],
                              np.asarray(sti["beacons_tx"]))
        assert np.array_equal(np.asarray(stb["app_done"])[i, 0],
                              np.asarray(sti["app_done"]))


def test_transport_knob_sweep_does_not_recompile():
    """c_hop is a traced knob: sweeping it under a fixed topology re-uses
    the compiled program."""
    p = _params(m=8, k=2, n_childs=4, max_apps=8, queue_cap=128,
                topology="mesh2d")
    wl = W.independent_batch(p, seeds=(0,), n_apps=1)
    SW.sweep(p.shape, SW.knob_batch(c_hop=(1.0, 4.0)), wl, 1e7,
             topology="mesh2d")
    c0 = SW.cache_size()
    SW.sweep(p.shape, SW.knob_batch(c_hop=(2.0, 16.0), dn_th=(1, 3)), wl,
             1e7, topology="mesh2d")
    assert SW.cache_size() == c0


# --- queue_impl="tree" (core/eventq.py, DESIGN.md §11) gates: the
# tournament-tree queue must reproduce every frozen golden bitwise — the
# structure reorders *work*, never results.

def test_tree_impl_matches_pre_refactor_golden():
    """The tournament-tree event queue reproduces the PR-2 frozen golden
    grid bitwise (same beacons, same app_done sha)."""
    p = _params(queue_impl="tree")
    wl = W.interference_batch(p, seeds=(0, 1), sim_len=3e5)
    stb = SW.sweep(p.shape, SW.knob_batch(dn_th=THRESHOLDS), wl, 3e5)
    assert np.asarray(stb["beacons_tx"]).tolist() == _GOLDEN_BEACONS
    done = np.asarray(stb["app_done"], np.float32)
    assert hashlib.sha256(done.tobytes()).hexdigest() == _GOLDEN_APP_DONE_SHA
    st1 = run(p, *W.independent_tasks(p, n_apps=1), 1e7)
    assert float(np.asarray(st1["app_done"])[0]) == 16240.0
    assert int(st1["beacons_tx"]) == 8


def test_tree_impl_matches_fig3b_spot_golden():
    """The fig3b-shaped spot grid (captured at 137008a) under the tree
    queue: identical beacons and app_done sha."""
    p = SimParams(m=64, k=16, n_childs=50, max_apps=128, queue_cap=2048,
                  queue_impl="tree")
    wl = W.interference_batch(p, seeds=(1,), sim_len=1e6)
    st_ = SW.sweep(p.shape, SW.knob_batch(dn_th=(1, 2, 4, 8, 16, 32)),
                   wl, 1e6)
    assert np.asarray(st_["beacons_tx"]).tolist() == _FIG3B_SPOT_BEACONS
    done = np.asarray(st_["app_done"], np.float32)
    assert hashlib.sha256(done.tobytes()).hexdigest() == _FIG3B_SPOT_SHA


@pytest.mark.parametrize("topology", ["hier_tree", "mesh2d"])
def test_tree_impl_matches_linear_on_nonideal_fabric(topology):
    """Tree and linear queues agree bitwise on the non-ideal fabrics too,
    where the k-1 BEACON_RX fan-out exercises big event batches."""
    p = _params(topology=topology)
    wl = W.interference_batch(p, seeds=(0,), sim_len=3e5)
    kn = SW.knob_batch(dn_th=(1, 4))
    lin = SW.sweep(p.shape, kn, wl, 3e5, topology=topology,
                   queue_impl="linear")
    tre = SW.sweep(p.shape, kn, wl, 3e5, topology=topology,
                   queue_impl="tree")
    for key in ("app_done", "app_arrive", "beacons_tx", "beacons_rx",
                "events_processed", "dropped", "mgmt_msgs", "mgmt_latency",
                "mgmt_proc", "bcn_skew_sum", "bcn_skew_max", "view",
                "view_t", "loads"):
        assert np.array_equal(np.asarray(lin[key]), np.asarray(tre[key])), key


def test_queue_impl_sweep_kwarg_overrides_shape():
    """sweep(queue_impl=...) swaps the static impl without mutating the
    caller's shape, and both impls share one compile per value."""
    p = _params()
    assert p.shape.queue_impl == "linear"
    wl = W.interference_batch(p, seeds=(0,), sim_len=1e5)
    kn = SW.knob_batch(dn_th=(2,))
    a = SW.sweep(p.shape, kn, wl, 1e5, queue_impl="tree")
    b = SW.sweep(p.shape, kn, wl, 1e5)
    assert p.shape.queue_impl == "linear"
    assert np.array_equal(np.asarray(a["app_done"]), np.asarray(b["app_done"]))


def test_sweep_simparams_roundtrips_all_static_axes():
    """Passing a full SimParams to sweep() round-trips EVERY static
    axis — policy, topology and queue_impl used to be silently dropped
    in favor of the defaults (ISSUE 5 satellite regression)."""
    p = _params(mapping="round_robin", beacon="periodic",
                topology="mesh2d", queue_impl="tree", T_b=700.0)
    wl = W.interference_batch(p, seeds=(0,), sim_len=2e5)
    kn = SW.knob_batch(dn_th=(2, 8), T_b=700.0)
    st_p = SW.sweep(p, kn, wl, 2e5)
    st_explicit = SW.sweep(p.shape, kn, wl, 2e5, policy=p.policy,
                           topology=p.topo)
    for key in ("app_done", "beacons_tx", "events_processed"):
        assert np.array_equal(np.asarray(st_p[key]),
                              np.asarray(st_explicit[key])), key
    # the non-default axes actually took effect: a default-axes sweep of
    # the same shape differs (mesh2d delivers beacons per receiver)
    st_default = SW.sweep(p.shape, kn, wl, 2e5)
    assert int(np.asarray(st_p["beacons_rx"]).sum()) > 0
    assert int(np.asarray(st_default["beacons_rx"]).sum()) == 0
    # explicit kwargs still win over the SimParams fields
    st_override = SW.sweep(p, kn, wl, 2e5, topology="ideal")
    assert int(np.asarray(st_override["beacons_rx"]).sum()) == 0


@given(st.sampled_from([2, 4, 8]), st.integers(0, 20))
@settings(max_examples=8, deadline=None)
def test_beacons_monotone_in_threshold(k, seed):
    """Property (paper Fig 3b): beacons_tx is monotone non-increasing in
    dn_th — a coarser threshold never produces more status traffic."""
    p = _params(k=k, n_childs=12)
    wl = W.interference_batch(p, seeds=(seed,), sim_len=2e5)
    st_ = SW.sweep(p.shape, SW.knob_batch(dn_th=(1, 2, 4, 8, 16)), wl, 2e5)
    b = SW.beacons(st_)[:, 0]
    assert (np.diff(b) <= 0).all(), f"not monotone: {b.tolist()}"
