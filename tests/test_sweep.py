"""Batched sweep engine: exactness vs per-config runs, compile caching,
and the beacon-threshold monotonicity property (paper Fig 3b)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import sweep as SW
from repro.core import workloads as W
from repro.core.sim import SimParams, run


def _params(k=4, **kw):
    kw.setdefault("m", 16)
    kw.setdefault("n_childs", 16)
    kw.setdefault("max_apps", 32)
    kw.setdefault("queue_cap", 512)
    return SimParams(k=k, **kw)


THRESHOLDS = (1, 2, 4, 8)


@pytest.mark.parametrize("mode", ["vmap", "seq"])
def test_sweep_matches_per_config_bitwise(mode):
    """A batched threshold sweep must be bitwise identical to per-config
    run() calls in BOTH execution modes — vmap batches the same
    computation, it doesn't change it."""
    p = _params()
    wl = W.interference_batch(p, seeds=(0, 1), sim_len=3e5)
    stb = SW.sweep(p.shape, SW.knob_batch(dn_th=THRESHOLDS), wl, 3e5,
                   mode=mode)
    for i, th in enumerate(THRESHOLDS):
        for s in range(2):
            pi = _params(dn_th=th)
            sti = run(pi, wl[0][s], wl[1][s], wl[2][s], 3e5)
            assert np.array_equal(np.asarray(stb["beacons_tx"])[i, s],
                                  np.asarray(sti["beacons_tx"]))
            assert np.array_equal(np.asarray(stb["app_done"])[i, s],
                                  np.asarray(sti["app_done"]))
            assert np.array_equal(np.asarray(stb["app_arrive"])[i, s],
                                  np.asarray(sti["app_arrive"]))


def test_cost_knob_sweep_matches_per_config():
    """Sweeping the traced cost knobs (c_s, c_b) also reproduces the
    per-config results exactly."""
    p = _params()
    wl = W.independent_batch(p, seeds=(0,), n_apps=2)
    knobs = SW.knob_batch(c_s=(1.0, 8.0, 64.0), c_b=(2.0, 8.0, 32.0))
    stb = SW.sweep(p.shape, knobs, wl, 1e7)
    for i, (cs, cb) in enumerate(zip((1.0, 8.0, 64.0), (2.0, 8.0, 32.0))):
        sti = run(_params(c_s=cs, c_b=cb), wl[0][0], wl[1][0], wl[2][0], 1e7)
        assert np.array_equal(np.asarray(stb["app_done"])[i, 0],
                              np.asarray(sti["app_done"]))


def test_run_does_not_recompile_on_knob_change():
    """dn_th / c_* are traced: changing them re-uses the XLA program."""
    from repro.core.sim import compile_cache_size
    p = _params(m=8, k=2, n_childs=4, max_apps=8, queue_cap=128)
    arr, gmns, lens = W.independent_tasks(p, n_apps=1)
    run(p, arr, gmns, lens, 1e7)
    c0 = compile_cache_size()
    for th, cs in ((1, 2.0), (7, 16.0), (3, 1.0)):
        run(_params(m=8, k=2, n_childs=4, max_apps=8, queue_cap=128,
                    dn_th=th, c_s=cs), arr, gmns, lens, 1e7)
    assert compile_cache_size() == c0


def test_sweep_compiles_once_per_shape():
    p = _params(m=8, k=2, n_childs=4, max_apps=8, queue_cap=128)
    wl = W.independent_batch(p, seeds=(0,), n_apps=1)
    SW.sweep(p.shape, SW.knob_batch(dn_th=(1, 2)), wl, 1e7)
    c0 = SW.cache_size()
    SW.sweep(p.shape, SW.knob_batch(dn_th=(4, 16)), wl, 1e7)
    SW.sweep(p.shape, SW.knob_batch(dn_th=(3, 5), c_s=2.0), wl, 1e7)
    assert SW.cache_size() == c0


def test_knob_batch_validation():
    kn = SW.knob_batch(dn_th=(1, 2, 4))
    assert kn.dn_th.shape == (3,) and kn.c_b.shape == (3,)
    with pytest.raises(ValueError):
        SW.knob_batch(dn_th=(1, 2), c_s=(1.0, 2.0, 3.0))
    prod = SW.knob_product(c_s=(1.0, 8.0), dn_th=(1, 2, 4))
    assert prod.dn_th.shape == (6,)
    assert np.asarray(prod.c_s).tolist() == [1.0] * 3 + [8.0] * 3


@given(st.sampled_from([2, 4, 8]), st.integers(0, 20))
@settings(max_examples=8, deadline=None)
def test_beacons_monotone_in_threshold(k, seed):
    """Property (paper Fig 3b): beacons_tx is monotone non-increasing in
    dn_th — a coarser threshold never produces more status traffic."""
    p = _params(k=k, n_childs=12)
    wl = W.interference_batch(p, seeds=(seed,), sim_len=2e5)
    st_ = SW.sweep(p.shape, SW.knob_batch(dn_th=(1, 2, 4, 8, 16)), wl, 2e5)
    b = SW.beacons(st_)[:, 0]
    assert (np.diff(b) <= 0).all(), f"not monotone: {b.tolist()}"
