"""Transaction-level simulator: conservation, determinism, paper shape."""
import numpy as np
import pytest

from repro.core import workloads as W
from repro.core.sim import SimParams, response_times, run, speedup


def _small(k=4, n_childs=16, **kw):
    return SimParams(m=16, k=k, n_childs=n_childs, max_apps=32,
                     queue_cap=512, **kw)


def test_single_app_completes():
    p = _small()
    arr, gmns, lens = W.independent_tasks(p, n_apps=1)
    st = run(p, arr, gmns, lens, sim_len=1e7)
    assert int(st["app_remaining"][0]) == 0
    assert float(st["app_done"][0]) < 1e17
    assert int(st["dropped"]) == 0
    # all loads drained
    assert int(np.asarray(st["loads"]).sum()) == 0


def test_deterministic():
    p = _small()
    arr, gmns, lens = W.independent_tasks(p, n_apps=2)
    a = run(p, arr, gmns, lens, 1e7)
    b = run(p, arr, gmns, lens, 1e7)
    assert float(a["app_done"][0]) == float(b["app_done"][0])
    assert int(a["beacons_tx"]) == int(b["beacons_tx"])


def test_speedup_at_least_serial():
    """Parallel response never slower than running childs back-to-back on
    one PE (sanity lower bound) and never faster than m-way ideal."""
    p = _small(k=4)
    arr, gmns, lens = W.independent_tasks(p, n_apps=1)
    st = run(p, arr, gmns, lens, 1e7)
    s = float(speedup(st, lens))
    assert int(response_times(st)[1].sum()) == 1
    assert 1.0 < s <= p.m


def test_k1_has_no_beacons():
    p = _small(k=1)
    arr, gmns, lens = W.independent_tasks(p, n_apps=1)
    st = run(p, arr, gmns, lens, 1e7)
    assert int(st["beacons_tx"]) == 0


def test_beacons_decrease_with_threshold():
    counts = []
    for th in (1, 4, 16):
        p = SimParams(m=64, k=8, n_childs=32, dn_th=th, max_apps=64,
                      queue_cap=1024)
        arr, gmns, lens = W.interference(p, sim_len=3e5, seed=0)
        st = run(p, arr, gmns, lens, 3e5)
        counts.append(int(st["beacons_tx"]))
    assert counts[0] >= counts[1] >= counts[2]


def test_clustered_beats_centralized_under_load():
    """The paper's core claim at small scale: k>1 beats k=1 when the
    centralized manager saturates."""
    res = {}
    for k in (1, 4):
        p = SimParams(m=64, k=k, n_childs=48, dn_th=2, max_apps=128,
                      queue_cap=2048, c_s=16.0)
        arr, gmns, lens = W.interference(p, sim_len=6e5, pair_period=4000,
                                         seed=0)
        st = run(p, arr, gmns, lens, 6e5)
        s = float(speedup(st, lens))
        assert int(response_times(st)[1].sum()) > 3
        res[k] = s
    assert res[4] > res[1]


def test_mapping_balances_single_cluster():
    """Within one cluster, min-search spreads childs evenly over PEs."""
    p = SimParams(m=8, k=1, n_childs=8, max_apps=4, queue_cap=256)
    arr, gmns, lens = W.independent_tasks(p, n_apps=1)
    st = run(p, arr, gmns, lens, 2e4)   # stop mid-flight
    # snapshot semantics differ; just assert it completed evenly: response
    # equals one task length + overhead (no PE got two tasks)
    tr = float(st["app_done"][0] - st["app_arrive"][0])
    assert tr < 2 * 16_000
