"""The dry-run entry point works end-to-end (subprocess: it must set
XLA_FLAGS before jax init).  One cheap cell per mesh keeps this fast."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("mesh", ["single", "multi"])
def test_dryrun_one_cell(mesh, tmp_path):
    out = tmp_path / "dr.json"
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "olmo_1b",
         "--shape", "decode_32k", "--mesh", mesh, "--out", str(out)],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=540)
    assert res.returncode == 0, res.stderr[-2000:]
    rows = json.loads(out.read_text())
    assert rows[0]["status"] == "ok"
    assert rows[0]["fits_16gb"]
    assert rows[0]["flops_per_chip"] > 0
    assert rows[0]["t_memory_s"] > 0
