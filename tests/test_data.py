"""Data pipeline: determinism, restart continuity, shard independence."""
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.data.pipeline import DataConfig, DataIterator, synth_batch


CFG = reduced_config(get_config("olmo_1b"))


def test_batch_deterministic():
    a = synth_batch(CFG, 4, 16, DataConfig(seed=1), step=5)
    b = synth_batch(CFG, 4, 16, DataConfig(seed=1), step=5)
    assert np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    c = synth_batch(CFG, 4, 16, DataConfig(seed=2), step=5)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))


def test_labels_shifted():
    b = synth_batch(CFG, 2, 16, DataConfig(), step=0)
    assert b["tokens"].shape == (2, 16) and b["labels"].shape == (2, 16)
    assert (np.asarray(b["tokens"])[:, 1:]
            == np.asarray(b["labels"])[:, :-1]).all()


def test_tokens_in_vocab():
    b = synth_batch(CFG, 8, 64, DataConfig(), step=3)
    t = np.asarray(b["tokens"])
    assert t.min() >= 0 and t.max() < CFG.vocab_size


def test_iterator_restart_continuity():
    """Restarting from step N yields exactly the batches a run that never
    crashed would have seen — the stateless-restart property."""
    it = DataIterator(CFG, 2, 8, DataConfig(seed=0), start_step=0)
    seq = [np.asarray(next(it)["tokens"]) for _ in range(6)]
    it.close()
    it2 = DataIterator(CFG, 2, 8, DataConfig(seed=0), start_step=3)
    seq2 = [np.asarray(next(it2)["tokens"]) for _ in range(3)]
    it2.close()
    for a, b in zip(seq[3:], seq2):
        assert np.array_equal(a, b)


def test_shards_disjoint_streams():
    a = synth_batch(CFG, 2, 8, DataConfig(seed=0, shard=0), 0)
    b = synth_batch(CFG, 2, 8, DataConfig(seed=0, shard=1), 0)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))


def test_frontend_extras():
    vl = reduced_config(get_config("internvl2_2b"))
    b = synth_batch(vl, 2, 8, DataConfig(), 0)
    assert b["patches"].shape == (2, vl.vision_tokens, vl.d_model)
    wh = reduced_config(get_config("whisper_medium"))
    b = synth_batch(wh, 2, 8, DataConfig(), 0)
    assert b["frames"].shape == (2, wh.enc_seq_len, wh.d_model)
