"""Checkpointing: roundtrip, atomic commit, GC, async, elastic re-put."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as CKPT


def _tree(key, scale=1.0):
    return {"a": jax.random.normal(key, (4, 8)) * scale,
            "b": {"c": jnp.arange(6, dtype=jnp.int32),
                  "d": jax.random.normal(jax.random.fold_in(key, 1), (3,))}}


def test_roundtrip(tmp_path, key):
    tree = _tree(key)
    CKPT.save(str(tmp_path), 7, tree)
    step, out = CKPT.restore(str(tmp_path), tree)
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        assert np.allclose(np.asarray(a), np.asarray(b))


def test_latest_and_gc(tmp_path, key):
    tree = _tree(key)
    for s in (10, 20, 30, 40):
        CKPT.save(str(tmp_path), s, tree, keep=2)
    assert CKPT.committed_steps(str(tmp_path)) == [30, 40]
    assert CKPT.latest_step(str(tmp_path)) == 40


def test_uncommitted_ignored(tmp_path, key):
    tree = _tree(key)
    CKPT.save(str(tmp_path), 5, tree)
    # simulate a crash mid-write of step 6: no COMMIT file
    path = os.path.join(str(tmp_path), "step_00000006")
    os.makedirs(path)
    with open(os.path.join(path, "meta.json"), "w") as f:
        f.write("{}")
    assert CKPT.latest_step(str(tmp_path)) == 5
    step, _ = CKPT.restore(str(tmp_path), tree)
    assert step == 5


def test_async_save(tmp_path, key):
    tree = _tree(key)
    _, thread = CKPT.save(str(tmp_path), 3, tree, async_=True)
    thread.join()
    step, out = CKPT.restore(str(tmp_path), tree)
    assert step == 3


def test_elastic_restore_reshards(tmp_path, key):
    """Restore onto explicit (single-device) shardings — the elastic path."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    tree = _tree(key)
    CKPT.save(str(tmp_path), 1, tree)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), tree)
    step, out = CKPT.restore(str(tmp_path), tree, shardings=sh)
    assert out["a"].sharding == NamedSharding(mesh, P())
