"""Message protocol pack/unpack (paper Table 1/2)."""
from hypothesis import given, settings, strategies as st

from repro.core.messages import (BROADCAST, Message, MsgType, beacon,
                                 join_exit, task_start)


@given(st.sampled_from(list(MsgType)), st.integers(0, 255),
       st.integers(-1, 255), st.integers(0, 7), st.integers(0, 1),
       st.lists(st.integers(-2**31, 2**31 - 1), max_size=3))
@settings(max_examples=100, deadline=None)
def test_roundtrip(typ, src, dst, prio, flag, data):
    m = Message(typ, src, dst, prio, flag, tuple(data))
    m2 = Message.unpack(m.pack())
    assert m2.type == typ and m2.src == src and m2.dst == dst
    assert m2.prio == prio and m2.flag == flag
    assert list(m2.data[:len(data)]) == list(data)


def test_helpers():
    b = beacon(3, 42)
    assert b.dst == BROADCAST and b.flag == 1 and b.data[0] == 42
    t = task_start(0, 5, 0x1000, 0x2000)
    assert t.type == MsgType.TASK_START and t.data == (0x1000, 0x2000)
    j = join_exit(7, 0, 0xBEEF)
    assert j.type == MsgType.JOIN_EXIT
