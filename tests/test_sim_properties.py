"""Hypothesis property tests on the TLM simulator's invariants."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import workloads as W
from repro.core.sim import SimParams, run


@st.composite
def sim_config(draw):
    k = draw(st.sampled_from([1, 2, 4, 8]))
    mpk = draw(st.sampled_from([1, 2, 4]))
    n_childs = draw(st.integers(1, 12))
    dn_th = draw(st.sampled_from([1, 2, 4, 8]))
    n_apps = draw(st.integers(1, 3))
    return SimParams(m=k * mpk, k=k, n_childs=n_childs, dn_th=dn_th,
                     max_apps=8, queue_cap=512), n_apps


@given(sim_config())
@settings(max_examples=15, deadline=None)
def test_all_apps_complete_and_loads_drain(cfg):
    p, n_apps = cfg
    arr, gmns, lens = W.independent_tasks(p, n_apps=n_apps)
    st_ = run(p, arr, gmns, lens, sim_len=1e9)
    done = np.asarray(st_["app_done"])[:n_apps]
    assert (done < 1e17).all(), "every submitted app must finish"
    assert int(np.asarray(st_["loads"]).sum()) == 0
    assert int(st_["dropped"]) == 0
    # response time at least one task length, at most serial execution
    arr_np = np.asarray(st_["app_arrive"])[:n_apps]
    tr = done - arr_np
    lens_np = np.asarray(lens)[:n_apps]
    assert (tr >= lens_np.max(axis=1) - 1e-3).all()
    # generous upper bound: all childs serial on one PE + per-event overhead
    bound = lens_np.sum(axis=1) * n_apps + 1e5
    assert (tr <= bound).all()


@given(sim_config())
@settings(max_examples=10, deadline=None)
def test_beacons_bounded_by_load_changes(cfg):
    p, n_apps = cfg
    arr, gmns, lens = W.independent_tasks(p, n_apps=n_apps)
    st_ = run(p, arr, gmns, lens, sim_len=1e9)
    # every mapped task changes a load twice (map + exit); each beacon needs
    # >= dn_th accumulated change at one GMN
    total_changes = 2 * n_apps * p.n_childs
    assert int(st_["beacons_tx"]) <= total_changes // p.dn_th + p.k


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=5, deadline=None)
def test_interference_workload_stable(seed):
    p = SimParams(m=16, k=4, n_childs=8, max_apps=64, queue_cap=1024)
    arr, gmns, lens = W.interference(p, sim_len=2e5, seed=seed)
    finite = arr[arr < 1e17]
    assert len(finite) >= 2 and len(finite) % 2 == 0
    pairs = finite.reshape(-1, 2)
    # within each pair the second app arrives after the first (Poisson
    # offset >= 0); pairs themselves may interleave when the offset
    # exceeds the pair period — that's the intended contention
    assert (pairs[:, 1] >= pairs[:, 0]).all()
    assert (np.diff(pairs[:, 0]) > 0).all()      # pair launches are periodic
    assert W.offered_load(p, 14_000.0) < 1.2
