"""Pallas two-stage min-search kernel vs oracle (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.hier_minsearch import assign_tasks

SHAPES = [(1, 4), (4, 8), (8, 8), (16, 4), (2, 2)]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("n_tasks", [1, 7, 32])
def test_assign_matches_ref(shape, n_tasks):
    k, mpk = shape
    key = jax.random.PRNGKey(k * 100 + n_tasks)
    loads = jax.random.uniform(key, (k, mpk)) * 5
    costs = jax.random.uniform(jax.random.fold_in(key, 1), (n_tasks,)) + 0.5
    a1, l1 = ref.assign_tasks_ref(loads, costs)
    a2, l2 = assign_tasks(loads, costs, interpret=True)
    assert np.array_equal(np.asarray(a1), np.asarray(a2))
    assert np.allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)


@pytest.mark.parametrize("shape", [(2, 2), (4, 8), (8, 4)])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_assign_matches_ref_with_ties(shape, seed):
    """Decision-for-decision equality on tie-heavy integer load matrices:
    small-integer loads and unit costs force repeated stage-1 and stage-2
    argmin ties, which both implementations must break identically (first
    occurrence, matching the hardware min-search scan order)."""
    k, mpk = shape
    rng = np.random.default_rng(seed)
    loads = jnp.asarray(rng.integers(0, 3, (k, mpk)), jnp.float32)
    costs = jnp.ones((3 * k * mpk,), jnp.float32)
    a1, l1 = ref.assign_tasks_ref(loads, costs)
    a2, l2 = assign_tasks(loads, costs, interpret=True)
    assert np.array_equal(np.asarray(a1), np.asarray(a2))
    assert np.array_equal(np.asarray(l1), np.asarray(l2))


def test_assign_all_zero_full_tie():
    """The fully degenerate case: every cluster and PE tied at zero.  The
    walk must be the deterministic first-index order in both paths."""
    loads = jnp.zeros((3, 3), jnp.float32)
    costs = jnp.ones((9,), jnp.float32)
    a1, _ = ref.assign_tasks_ref(loads, costs)
    a2, _ = assign_tasks(loads, costs, interpret=True)
    assert np.array_equal(np.asarray(a1), np.asarray(a2))
    # every (cluster, pe) visited exactly once before any repeats
    seen = {tuple(r) for r in np.asarray(a1).tolist()}
    assert len(seen) == 9


def test_ops_dispatch_routes_through_kernel():
    """core/mapping's batch path reaches the Pallas kernel (interpret on
    CPU) via kernels.ops, and matches the oracle through that route."""
    from repro.core.mapping import MapperState, map_batch
    state = MapperState.create(k=4, m_per_k=4)
    assigns, new_state = map_batch(state, np.ones(8, np.float32))
    ra, rl = ref.assign_tasks_ref(jnp.zeros((4, 4), jnp.float32),
                                  jnp.ones((8,), jnp.float32))
    assert np.array_equal(np.asarray(assigns), np.asarray(ra))
    assert np.allclose(np.asarray(new_state.loads), np.asarray(rl))


def test_two_stage_differs_from_flat_argmin():
    """The hierarchy is load-sum driven: a cluster with the globally
    lightest PE but the heaviest total is NOT picked (paper Sec 4.1)."""
    loads = jnp.asarray([[0.0, 9.0, 9.0],     # cluster 0: lightest PE, heavy total
                         [2.0, 2.0, 2.0]])    # cluster 1: lighter total
    c, p = ref.hier_minsearch_ref(loads)
    assert int(c) == 1
