"""Pallas two-stage min-search kernel vs oracle (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.hier_minsearch import assign_tasks

SHAPES = [(1, 4), (4, 8), (8, 8), (16, 4), (2, 2)]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("n_tasks", [1, 7, 32])
def test_assign_matches_ref(shape, n_tasks):
    k, mpk = shape
    key = jax.random.PRNGKey(k * 100 + n_tasks)
    loads = jax.random.uniform(key, (k, mpk)) * 5
    costs = jax.random.uniform(jax.random.fold_in(key, 1), (n_tasks,)) + 0.5
    a1, l1 = ref.assign_tasks_ref(loads, costs)
    a2, l2 = assign_tasks(loads, costs, interpret=True)
    assert np.array_equal(np.asarray(a1), np.asarray(a2))
    assert np.allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)


def test_two_stage_differs_from_flat_argmin():
    """The hierarchy is load-sum driven: a cluster with the globally
    lightest PE but the heaviest total is NOT picked (paper Sec 4.1)."""
    loads = jnp.asarray([[0.0, 9.0, 9.0],     # cluster 0: lightest PE, heavy total
                         [2.0, 2.0, 2.0]])    # cluster 1: lighter total
    c, p = ref.hier_minsearch_ref(loads)
    assert int(c) == 1
