"""Pallas flash attention: shape/dtype sweep vs the jnp oracle
(interpret=True executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention

CASES = [
    # (B, Sq, Skv, Hq, Hkv, D, causal, window)
    (2, 128, 128, 4, 2, 64, True, 0),
    (1, 256, 256, 4, 4, 32, True, 0),
    (2, 128, 128, 8, 2, 64, False, 0),
    (1, 256, 256, 2, 2, 64, True, 64),      # sliding window
    (1, 192, 192, 2, 1, 64, True, 0),       # non-multiple of block
    (1, 128, 256, 2, 2, 64, True, 0),       # Sq < Skv (chunked prefill)
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_vs_ref(case, dtype):
    B, Sq, Skv, Hq, Hkv, D, causal, win = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, Skv, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, Skv, Hkv, D), dtype)
    out = flash_attention(q, k, v, causal=causal, sliding_window=win,
                          block_q=64, block_k=64, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, sliding_window=win)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    assert jnp.abs(out.astype(jnp.float32)
                   - want.astype(jnp.float32)).max() < tol


def test_block_shape_independence():
    """Result must not depend on the BlockSpec tiling."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))
    outs = [flash_attention(q, k, v, block_q=bq, block_k=bk, interpret=True)
            for bq, bk in [(64, 64), (128, 64), (64, 128), (256, 256)]]
    for o in outs[1:]:
        assert jnp.allclose(o, outs[0], atol=1e-5)
