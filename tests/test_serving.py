"""Serving control plane: two-stage placement, beacons, failure recovery."""
import numpy as np
import pytest

from repro.serving.engine import ClusterScheduler, FleetSim, Request


def _reqs(n, max_new=16):
    return [Request(sort_key=float(i), rid=i, prompt_len=64, max_new=max_new)
            for i in range(n)]


def test_two_stage_balances_across_clusters():
    fleet = FleetSim(k=4, groups_per_cluster=4, dn_th=1)
    for r in _reqs(64):
        fleet.submit(r)
    per_cluster = fleet.loads().sum(axis=1)
    assert per_cluster.max() / per_cluster.min() < 1.3
    assert fleet.imbalance() < 1.3


def test_beacon_volume_scales_with_threshold():
    counts = {}
    for th in (1, 8):
        fleet = FleetSim(k=4, groups_per_cluster=4, dn_th=th)
        for r in _reqs(128):
            fleet.submit(r)
        while fleet.active:
            fleet.tick()
        counts[th] = fleet.beacons_tx
    assert counts[1] > counts[8]


def test_requests_complete():
    fleet = FleetSim(k=2, groups_per_cluster=2, dn_th=4)
    reqs = _reqs(16, max_new=8)
    for r in reqs:
        fleet.submit(r)
    for _ in range(100):
        if not fleet.active:
            break
        fleet.tick()
    assert len(fleet.finished) == 16
    assert all(r.finished_at >= 0 for r in reqs)
    # all load released
    assert fleet.loads().sum() == pytest.approx(0.0, abs=1e-9)


def test_failure_requeues_and_tombstones():
    fleet = FleetSim(k=2, groups_per_cluster=2, dn_th=4)
    for r in _reqs(16):
        fleet.submit(r)
    orphans = fleet.kill(0, 0)
    assert orphans > 0
    # dead group never picked again
    for r in _reqs(32):
        fleet.submit(r)
    assert fleet.schedulers[0].local[0] == 0.0
    for _ in range(200):
        if not fleet.active:
            break
        fleet.tick()
    assert len(fleet.finished) == 48     # nothing lost


def test_stale_view_still_places():
    """With a huge threshold views go stale; placement must still work and
    skew toward the entry scheduler's own exact view."""
    fleet = FleetSim(k=4, groups_per_cluster=2, dn_th=10_000)
    for r in _reqs(64):
        fleet.submit(r)
    assert fleet.beacons_tx == 0
    assert fleet.loads().sum() > 0


def test_active_keys_no_collision_with_1000_plus_groups():
    """Regression: the composite int key `cluster * 1000 + g` silently
    collided for >= 1000 groups per cluster (cluster 0 group 1000 aliased
    cluster 1 group 0).  Keys are (cluster, group) tuples now."""
    n_groups = 1100
    fleet = FleetSim(k=2, groups_per_cluster=n_groups, dn_th=10**9)
    for r in _reqs(2 * n_groups):
        fleet.submit(r)
    # each group of each cluster holds exactly one request, no aliasing
    assert len(fleet.active) == 2 * n_groups
    assert all(isinstance(key, tuple) for key in fleet.active)
    assert (0, 1000) in fleet.active and (1, 0) in fleet.active
    assert sum(len(v) for v in fleet.active.values()) == 2 * n_groups


def test_transport_delays_beacon_delivery():
    """Under a non-ideal topology a fired beacon reaches remote
    schedulers only after its per-receiver delay — views are stale in
    between, then catch up; nothing is lost."""
    fleet = FleetSim(k=4, groups_per_cluster=2, dn_th=1,
                     topology="mesh2d", msg_delay=2.0, hop_delay=1.0)
    for r in _reqs(8):
        fleet.submit(r)
    assert fleet.beacons_tx > 0
    assert fleet.pending, "deliveries must be in flight, not instant"
    # before any tick no remote view has updated
    assert fleet.beacons_rx == 0
    for _ in range(16):
        fleet.tick()
    assert not fleet.pending
    assert fleet.beacons_rx == fleet.beacons_tx * (fleet.k - 1)


def test_transport_receivers_hear_at_different_times():
    """shared_bus serializes the fan-out: receivers record different
    beacon receipt times (heterogeneous remote_t ages)."""
    fleet = FleetSim(k=4, groups_per_cluster=2, dn_th=1,
                     topology="shared_bus", msg_delay=1.0)
    fleet.submit(_reqs(1)[0])
    assert fleet.beacons_tx == 1
    for _ in range(8):
        fleet.tick()
    src = next(s.cid for s in fleet.schedulers
               if s.tx_log and s.tx_log[-1].type.name == "STATUS_BEACON")
    times = [fleet.schedulers[c].remote_t[src]
             for c in range(fleet.k) if c != src]
    assert len(set(times)) == len(times), times


def test_ideal_topology_is_instant_like_before():
    """The default fabric keeps the historical instant fan-out: no
    pending queue, views update at fire time."""
    fleet = FleetSim(k=4, groups_per_cluster=2, dn_th=1)
    for r in _reqs(8):
        fleet.submit(r)
    assert fleet.beacons_tx > 0
    assert not fleet.pending
    assert fleet.beacons_rx == fleet.beacons_tx * (fleet.k - 1)


def test_scheduler_message_log_types():
    from repro.core.messages import MsgType
    s = ClusterScheduler(0, 2, 2, dn_th=1)
    r = Request(sort_key=0.0, rid=1)
    s.place_local(r)
    s.maybe_beacon()
    kinds = {m.type for m in s.tx_log}
    assert MsgType.TASK_START in kinds
    assert MsgType.STATUS_BEACON in kinds


# -- management-fabric faults (DESIGN.md §13) -------------------------------

def test_fabric_kill_then_heal_loses_no_request():
    """Link and GMN failures mid-stream: every submitted request still
    finishes, losses and detours are counted, and after healing the
    beacon-conservation law holds once the fabric drains."""
    fleet = FleetSim(k=4, groups_per_cluster=4, dn_th=1)
    rid = 0
    def pump(n):
        nonlocal rid
        for _ in range(n):
            fleet.submit(Request(sort_key=fleet.t, rid=rid, max_new=8))
            rid += 1
            fleet.tick()
    pump(40)
    fleet.fail_link(0, 1)
    fleet.fail_gmn(2)
    pump(40)
    fleet.heal_link(0, 1)
    fleet.heal_gmn(2)
    pump(40)
    for _ in range(5000):
        if not fleet.active:
            break
        fleet.tick()
    assert len(fleet.finished) == rid, "no request may be lost"
    assert fleet.msgs_lost > 0 and fleet.reroutes > 0
    assert fleet.downtime > 0
    assert fleet.beacons_rx + fleet.msgs_lost \
        == (fleet.k - 1) * fleet.beacons_tx
    assert fleet.gmn_alive.all() and fleet.link_up.all()


def test_dead_gmn_receives_no_placements_and_heals_back():
    """While a manager is down nothing places on its cluster (min_search
    takeover re-homes stage-1 picks); after the heal it serves again."""
    fleet = FleetSim(k=3, groups_per_cluster=2, dn_th=1)
    fleet.fail_gmn(1)
    for i in range(12):
        fleet.submit(Request(sort_key=fleet.t, rid=i, max_new=4))
        fleet.tick()
    assert all(key[0] != 1 for key in fleet.active), \
        "dead cluster must not receive work"
    assert fleet.reroutes > 0
    fleet.heal_gmn(1)
    for i in range(12, 48):
        fleet.submit(Request(sort_key=fleet.t, rid=i, max_new=4))
        fleet.tick()
    assert any(key[0] == 1 for key in fleet.active) \
        or any(r.cluster == 1 for r in fleet.finished)


def test_fail_gmn_guards():
    fleet = FleetSim(k=2, groups_per_cluster=2, dn_th=1)
    fleet.fail_gmn(0)
    fleet.fail_gmn(0)                    # idempotent
    with pytest.raises(RuntimeError):
        fleet.fail_gmn(1)                # never kill the last live GMN
    fleet.heal_gmn(0)
    fleet.heal_gmn(0)                    # idempotent
    assert fleet.gmn_alive.all()
