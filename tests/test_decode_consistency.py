"""Decode-vs-forward consistency: feeding tokens one at a time through the
cached decode path must reproduce the teacher-forced forward logits.

This is the strongest end-to-end correctness check for the KV cache, RoPE
offsets, SWA ring buffer, SSM state carry and hybrid interleaving.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced_config
from repro.models import model as MDL

# MoE archs excluded from exact equality: capacity-based token dropping
# depends on grouping, which differs between the two paths by design.
EXACT_ARCHS = ["qwen2_72b", "olmo_1b", "glm4_9b", "minicpm_2b",
               "falcon_mamba_7b", "internvl2_2b"]


@pytest.mark.parametrize("arch", EXACT_ARCHS)
def test_decode_matches_forward(arch, key):
    cfg = reduced_config(get_config(arch))
    B, S = 2, 12
    params = MDL.init_model(key, cfg, jnp.float32)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    extra = {}
    if cfg.frontend == "vision":
        # frontends prepend tokens; decode-side handling of the prefix is a
        # prefill concern — test text-only here
        cfg = reduced_config(get_config(arch), vision_tokens=0)
        import dataclasses
        cfg = dataclasses.replace(cfg, frontend="none")
        params = MDL.init_model(key, cfg, jnp.float32)

    full_logits, _ = MDL.forward(params, cfg, tokens, extra=extra,
                                 remat="none")
    cache = MDL.init_cache(cfg, B, S + 2, jnp.float32)
    outs = []
    for t in range(S):
        logits, cache = MDL.decode_step(params, cfg, cache, tokens[:, t:t+1],
                                        jnp.int32(t))
        outs.append(logits[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    err = jnp.abs(dec_logits - full_logits).max()
    assert err < 2e-3, f"{arch}: decode/forward mismatch {err}"


def test_swa_ring_matches_forward():
    """Mixtral-style sliding window: ring cache equals windowed forward."""
    cfg = reduced_config(get_config("mixtral_8x22b"))
    import dataclasses
    cfg = dataclasses.replace(cfg, moe=None, d_ff=64, sliding_window=6)
    key = jax.random.PRNGKey(7)
    B, S = 1, 14                      # S > 2*window exercises wraparound
    params = MDL.init_model(key, cfg, jnp.float32)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full_logits, _ = MDL.forward(params, cfg, tokens, remat="none")
    cache = MDL.init_cache(cfg, B, S, jnp.float32)
    outs = []
    for t in range(S):
        logits, cache = MDL.decode_step(params, cfg, cache, tokens[:, t:t+1],
                                        jnp.int32(t))
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    err = jnp.abs(dec - full_logits).max()
    assert err < 2e-3, err


def test_hybrid_decode_matches_forward():
    """Jamba-like hybrid without MoE: mamba+attn interleave decodes right."""
    cfg = reduced_config(get_config("jamba_v01_52b"))
    import dataclasses
    cfg = dataclasses.replace(cfg, moe=None, d_ff=64)
    key = jax.random.PRNGKey(9)
    B, S = 1, 10
    params = MDL.init_model(key, cfg, jnp.float32)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full_logits, _ = MDL.forward(params, cfg, tokens, remat="none")
    cache = MDL.init_cache(cfg, B, S, jnp.float32)
    outs = []
    for t in range(S):
        logits, cache = MDL.decode_step(params, cfg, cache, tokens[:, t:t+1],
                                        jnp.int32(t))
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    err = jnp.abs(dec - full_logits).max()
    assert err < 2e-3, err
