"""Chaos harness for the fault-injection subsystem (core/faults.py,
DESIGN.md §13): the bitwise no-fault anchor against the frozen PR-2 and
PR-4 goldens, seq/vmap reproducibility under faults, the generalized
beacon-conservation law ``rx + lost == (k-1) * tx``, partition-and-heal
drain, exact downtime accounting, GMN takeover re-homing, seeded
determinism, and the no-recompile contract for fault-schedule grids."""
import hashlib

import numpy as np
import pytest

from repro.core import sweep as SW
from repro.core import workloads as W
from repro.core.faults import FaultSchedule, FaultSpec, pad_to
from repro.core.sim import SimParams, run

from test_sweep import _GOLDEN_APP_DONE_SHA, _GOLDEN_BEACONS, THRESHOLDS


def _params(k=4, **kw):
    kw.setdefault("m", 16)
    kw.setdefault("n_childs", 16)
    kw.setdefault("max_apps", 32)
    kw.setdefault("queue_cap", 512)
    return SimParams(k=k, **kw)


NON_IDEAL = ("shared_bus", "hier_tree", "mesh2d")


# -- the bitwise no-fault contract ------------------------------------------

@pytest.mark.parametrize("queue_impl", ["linear", "tree"])
def test_none_faults_reproduce_frozen_goldens_bitwise(queue_impl):
    """Compiling the fault machinery in with zero events (FaultSpec.none())
    must reproduce the PR-2 frozen golden grid — and the PR-4 tree-queue
    capture — bitwise: on an all-up mask every fault code path is an
    exact no-op."""
    p = _params(queue_impl=queue_impl)
    wl = W.interference_batch(p, seeds=(0, 1), sim_len=3e5)
    st = SW.sweep(p.shape, SW.knob_batch(dn_th=THRESHOLDS), wl, 3e5,
                  faults=FaultSpec.none())
    assert np.asarray(st["beacons_tx"]).tolist() == _GOLDEN_BEACONS
    done = np.asarray(st["app_done"], np.float32)
    assert hashlib.sha256(done.tobytes()).hexdigest() == _GOLDEN_APP_DONE_SHA
    assert int(np.asarray(st["msgs_lost"]).sum()) == 0
    assert int(np.asarray(st["reroutes"]).sum()) == 0
    assert float(np.asarray(st["downtime"]).sum()) == 0.0


def test_none_faults_match_no_faults_run_bitwise():
    """run(faults=FaultSpec.none()) equals run(faults=None) bitwise on
    every shared state leaf, on every topology."""
    for topology in ("ideal",) + NON_IDEAL:
        p = _params(topology=topology)
        wl = W.interference(p, seed=0, sim_len=2e5)
        st0 = run(p, *wl, 2e5)
        st1 = run(p, *wl, 2e5, faults=FaultSpec.none())
        for leaf in st0:
            a, b = np.asarray(st0[leaf]), np.asarray(st1[leaf])
            assert a.tobytes() == b.tobytes(), (topology, leaf)


# -- reproducibility --------------------------------------------------------

def test_seq_vmap_bitwise_under_faults():
    """The dispatch mode must not change faulty results: seq and vmap
    sweeps under the same fault schedule agree bitwise on every leaf."""
    p = _params(topology="hier_tree")
    wl = W.interference_batch(p, seeds=(0,), sim_len=2e5)
    kn = SW.knob_batch(dn_th=(2, 8))
    fs = FaultSpec.poisson_links(rate=2e-4, repair=2e4, seed=3)
    a = SW.sweep(p.shape, kn, wl, 2e5, mode="seq", topology="hier_tree",
                 faults=fs)
    b = SW.sweep(p.shape, kn, wl, 2e5, mode="vmap", topology="hier_tree",
                 faults=fs)
    assert int(np.asarray(a["msgs_lost"]).sum()) > 0
    for key in a:
        assert np.array_equal(np.asarray(a[key]), np.asarray(b[key])), key


def test_same_fault_seed_bitwise_same_different_seed_differs():
    """Seeded fault generators are deterministic: the same seed gives
    bitwise-identical runs, a different seed a different fabric history."""
    p = _params(topology="mesh2d")
    wl = W.interference(p, seed=1, sim_len=3e5)
    mk = lambda s: FaultSpec.poisson_links(rate=2e-4, repair=2e4, seed=s)
    st_a = run(p, *wl, 3e5, faults=mk(5))
    st_b = run(p, *wl, 3e5, faults=mk(5))
    st_c = run(p, *wl, 3e5, faults=mk(6))
    for leaf in st_a:
        assert np.asarray(st_a[leaf]).tobytes() \
            == np.asarray(st_b[leaf]).tobytes(), leaf
    assert any(np.asarray(st_a[leaf]).tobytes()
               != np.asarray(st_c[leaf]).tobytes() for leaf in st_a)


# -- conservation under loss ------------------------------------------------

@pytest.mark.parametrize("topology", NON_IDEAL)
def test_beacon_conservation_generalizes_under_faults(topology):
    """Every fired beacon either arrives or is counted lost:
    ``beacons_rx + msgs_lost == (k-1) * beacons_tx``, with the in-flight
    matrix drained and loss actually exercised (msgs_lost > 0)."""
    p = _params(topology=topology, dn_th=1)
    wl = W.interference(p, seed=0, sim_len=3e5)
    fs = FaultSpec.poisson_links(rate=3e-4, repair=3e4, seed=2)
    st = run(p, *wl, 3e5, faults=fs)
    tx, rx = int(st["beacons_tx"]), int(st["beacons_rx"])
    lost = int(st["msgs_lost"])
    assert tx > 0 and lost > 0
    assert rx + lost == (p.k - 1) * tx, (rx, lost, tx)
    assert (np.asarray(st["bcn_t"]) >= 1e17).all(), \
        "in-flight matrix must drain"
    assert int(st["dropped"]) == 0


def test_partition_and_heal_drains_and_completes():
    """A mesh2d fabric partition loses cross-cut beacons while down, the
    reliable control messages keep every application completing, the
    in-flight matrix drains after the heal, and downtime equals the cut
    size times the outage exactly."""
    p = _params(topology="mesh2d", dn_th=1)
    wl = W.interference(p, seed=0, sim_len=3e5)
    t_down, t_heal = 8e4, 1.5e5
    fs = FaultSpec.partition(t_down=t_down, t_heal=t_heal)
    st = run(p, *wl, 3e5, faults=fs)
    tx, rx = int(st["beacons_tx"]), int(st["beacons_rx"])
    lost = int(st["msgs_lost"])
    assert lost > 0
    assert rx + lost == (p.k - 1) * tx
    assert (np.asarray(st["bcn_t"]) >= 1e17).all(), \
        "in-flight matrix must drain after the heal"
    # every arrived application still completes (reliable control plane)
    arr = np.asarray(st["app_arrive"])
    done = np.asarray(st["app_done"])
    assert (done[arr < 1e17] < 1e17).all()
    # cut = 2 GMNs vs 2 GMNs, both directions: 8 directed links
    assert float(st["downtime"]) == 8 * (t_heal - t_down)
    assert (np.asarray(st["link_up"]) == 1.0).all()


def test_gmn_churn_rehomes_work_and_completes():
    """Scripted GMN failures re-home arrivals to live managers (the
    min_search takeover recorded in dec_gmn) and every application still
    completes; healed GMNs return to service."""
    p = _params(topology="hier_tree", record_s1=True, dn_th=2)
    wl = W.interference(p, seed=1, sim_len=3e5)
    fs = FaultSpec.scripted([
        (4e4, "gmn_fail", 1, 0), (5e4, "gmn_fail", 3, 0),
        (1.6e5, "gmn_heal", 1, 0), (2.1e5, "gmn_heal", 3, 0)])
    st = run(p, *wl, 3e5, faults=fs)
    arr = np.asarray(st["app_arrive"])
    done_mask = arr < 1e17
    assert (np.asarray(st["app_done"])[done_mask] < 1e17).all()
    # some arrivals landed on a dead GMN and were taken over
    rehomed = np.asarray(st["dec_gmn"])[done_mask] \
        != np.asarray(wl[1])[done_mask]
    assert rehomed.sum() > 0
    assert int(st["reroutes"]) > 0
    assert (np.asarray(st["gmn_alive"]) == 1.0).all()
    # takeover targets were alive at decision time
    assert float(st["downtime"]) == (1.6e5 - 4e4) + (2.1e5 - 5e4)


def test_downtime_counts_completed_outages_only():
    """downtime is accounted at the heal: an outage still open at the
    end of the run contributes nothing, overlapping failures merge."""
    p = _params(topology="hier_tree")
    wl = W.interference(p, seed=0, sim_len=2e5)
    fs = FaultSpec.scripted([
        (1e4, "link_down", 0, 1), (3e4, "link_down", 0, 1),   # merges
        (5e4, "link_up", 0, 1), (6e4, "link_up", 0, 1),       # idempotent
        (9e4, "link_down", 2, 3)])                            # never heals
    st = run(p, *wl, 2e5, faults=fs)
    assert float(st["downtime"]) == 5e4 - 1e4
    up = np.asarray(st["link_up"])
    assert up[0, 1] == 1.0 and up[2, 3] == 0.0


# -- compile behavior -------------------------------------------------------

def test_fault_schedule_grid_does_not_recompile():
    """Fault schedules are traced: a grid of seeds/intensities with one
    schedule length re-uses the compiled fault-aware program (the
    fault_frontier no-recompile claim)."""
    p = _params(m=8, k=2, n_childs=4, max_apps=8, queue_cap=128)
    wl = W.independent_batch(p, seeds=(0,), n_apps=1)
    kn = SW.knob_batch(dn_th=(1, 2))
    SW.sweep(p.shape, kn, wl, 1e5,
             faults=FaultSpec.poisson_links(rate=1e-3, seed=0))
    c0 = SW.cache_size()
    for seed in (1, 2, 3):
        SW.sweep(p.shape, kn, wl, 1e5,
                 faults=FaultSpec.poisson_links(rate=2e-3, seed=seed))
    assert SW.cache_size() == c0


# -- spec construction and serialization ------------------------------------

def test_faultspec_validation_and_padding():
    with pytest.raises(ValueError):
        FaultSpec(kind="meteor_strike")
    with pytest.raises(ValueError):
        FaultSpec.scripted([(1.0, "flood", 0, 1)])
    with pytest.raises(ValueError):
        FaultSpec.scripted([(1.0, "link_down", 9, 0)]).build(4, 1e5)
    sched = FaultSpec.partition(t_down=1e3).build(4, 1e5)
    padded = pad_to(sched, sched.capacity + 5)
    assert padded.capacity == sched.capacity + 5
    assert np.all(np.asarray(padded.times[sched.capacity:]) >= 1e17)
    with pytest.raises(ValueError):
        pad_to(padded, 1)
    assert isinstance(sched, FaultSchedule)


def test_faultspec_dict_roundtrip_rejects_unknown_fields():
    """from_dict is strict — an unknown field errors instead of being
    silently dropped (the schema-v5-payload-in-old-reader regression)."""
    fs = FaultSpec.poisson_links(rate=5e-4, repair=1e4, seed=7, name="x")
    assert FaultSpec.from_dict(fs.to_dict()) == fs
    sc = FaultSpec.scripted([(1.0, "gmn_fail", 1, 0)])
    assert FaultSpec.from_dict(sc.to_dict()) == sc
    bad = dict(fs.to_dict(), blast_radius=2)
    with pytest.raises(ValueError, match="blast_radius"):
        FaultSpec.from_dict(bad)
