"""Test fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches see the
host's single device; only launch/dryrun.py forces 512 placeholder devices.

When the real ``hypothesis`` package is missing (it is not baked into the
runtime image), install the deterministic fallback from _hypothesis_stub so
the property tests still collect and run; CI installs real hypothesis (see
pyproject.toml / .github/workflows/ci.yml) and takes priority here.
"""
import sys

try:
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_stub

    sys.modules["hypothesis"] = _hypothesis_stub
    sys.modules["hypothesis.strategies"] = _hypothesis_stub.strategies

import jax
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
