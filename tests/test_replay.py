"""Trace replay: the wall-clock control plane must reproduce the
tick-domain simulator's stage-1 decisions on recorded real traces
(serving/replay.py), beyond the synthetic per-decision unit tests of
test_policies.py."""
import numpy as np
import pytest

from repro.core import workloads as W
from repro.core.sim import SimParams, run
from repro.serving import replay as R


def _params(mapping, **kw):
    kw.setdefault("m", 16)
    kw.setdefault("k", 4)
    kw.setdefault("n_childs", 16)
    kw.setdefault("max_apps", 32)
    kw.setdefault("queue_cap", 512)
    return SimParams(mapping=mapping, record_s1=True, **kw)


@pytest.mark.parametrize("mapping", ["min_search", "round_robin",
                                     "hashed_random", "staleness_weighted"])
@pytest.mark.parametrize("topology", ["ideal", "mesh2d"])
def test_replay_decisions_agree_exactly(mapping, topology):
    """Every stage-1 decision of a full interference run — stale views,
    staleness ages, round-robin pointers and all — replays identically
    through the serving engine's host adapters."""
    p = _params(mapping, topology=topology)
    wl = W.interference(p, sim_len=3e5, seed=0)
    st = run(p, *wl, 3e5)
    trace = R.decision_trace(st, wl[1])
    assert len(trace) > 50, "trace must cover a real workload"
    report = R.replay_decisions(trace, p)
    assert report.agreement == 1.0, report.mismatches[:3]


def test_replay_staleness_weighted_infinite_T_b():
    """Regression: T_b=inf degenerates staleness_weighted to min_search
    in the tick domain; replay must evaluate the same degenerate policy
    (not substitute a finite period) and still agree 100%."""
    p = _params("staleness_weighted", topology="mesh2d", T_b=float("inf"))
    wl = W.interference(p, sim_len=3e5, seed=0)
    st = run(p, *wl, 3e5)
    trace = R.decision_trace(st, wl[1])
    report = R.replay_decisions(trace, p)
    assert report.agreement == 1.0, report.mismatches[:3]


def test_replay_trace_sees_heterogeneous_views():
    """Recorded traces under a non-ideal fabric contain genuinely
    heterogeneous staleness ages (the point of deviation §8.2)."""
    p = _params("staleness_weighted", topology="shared_bus")
    wl = W.interference(p, sim_len=3e5, seed=0)
    st = run(p, *wl, 3e5)
    trace = R.decision_trace(st, wl[1])
    hetero = any(len({round(float(a), 3) for j, a in enumerate(d.age)
                      if j != d.gmn}) > 1 for d in trace)
    assert hetero, "no decision saw heterogeneous remote ages"


def test_decision_trace_requires_recording():
    p = SimParams(m=16, k=4, n_childs=16, max_apps=32, queue_cap=512)
    wl = W.interference(p, sim_len=2e5, seed=0)
    st = run(p, *wl, 2e5)
    with pytest.raises(ValueError, match="record_s1"):
        R.decision_trace(st, wl[1])


def test_record_s1_does_not_change_results():
    """Recording is observation only: app_done/beacons are bitwise equal
    with and without it."""
    base = SimParams(m=16, k=4, n_childs=16, max_apps=32, queue_cap=512)
    rec = SimParams(m=16, k=4, n_childs=16, max_apps=32, queue_cap=512,
                    record_s1=True)
    wl = W.interference(base, sim_len=2e5, seed=0)
    st0 = run(base, *wl, 2e5)
    st1 = run(rec, *wl, 2e5)
    assert np.array_equal(np.asarray(st0["app_done"]),
                          np.asarray(st1["app_done"]))
    assert int(st0["beacons_tx"]) == int(st1["beacons_tx"])


def test_replay_trace_drives_fleetsim_end_to_end():
    """A recorded TLM arrival sequence drives FleetSim to completion:
    every recorded application becomes a finished request, submitted
    through its recorded entry cluster."""
    p = _params("min_search")
    wl = W.interference(p, sim_len=3e5, seed=0)
    st = run(p, *wl, 3e5)
    fleet = R.replay_trace(st, wl, p)
    n_apps = int((np.asarray(st["app_arrive"]) < 1e17).sum())
    assert n_apps > 0
    assert len(fleet.finished) == n_apps
    assert not fleet.active and not fleet.pending
    assert fleet.loads().sum() == pytest.approx(0.0, abs=1e-9)


def test_faulty_run_replays_at_full_agreement():
    """The chaos cross-check (DESIGN.md §13): a recorded tick-domain run
    under GMN churn and link failures — takeover re-homing included —
    replays through the wall-clock scheduler at 100% decision agreement.
    ``dec_gmn`` records the post-takeover decider and ``dec_view`` the
    dead-masked view the policy actually saw, so the host adapter faces
    exactly the same inputs."""
    from repro.core.faults import FaultSpec
    p = _params("min_search", topology="hier_tree", dn_th=2)
    wl = W.interference(p, sim_len=3e5, seed=1)
    fs = FaultSpec.scripted([
        (4e4, "gmn_fail", 1, 0), (5e4, "gmn_fail", 3, 0),
        (1.6e5, "gmn_heal", 1, 0), (2.1e5, "gmn_heal", 3, 0),
        (6e4, "link_down", 0, 2), (1.2e5, "link_up", 0, 2)])
    st = run(p, *wl, 3e5, faults=fs)
    state = {k: np.asarray(v) for k, v in st.items()}
    done = state["app_arrive"] < 1e17
    rehomed = (state["dec_gmn"][done] != np.asarray(wl[1])[done]).sum()
    assert rehomed > 0, "churn must actually re-home some arrivals"
    trace = R.decision_trace(state, wl[1])
    assert len(trace) > 50
    report = R.replay_decisions(trace, p)
    assert report.agreement == 1.0, report.mismatches[:3]
