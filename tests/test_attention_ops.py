"""flash_attention_xla (chunked custom-VJP) vs naive reference: fwd + grad."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref


def _qkv(B, Sq, Skv, Hq, Hkv, D, dtype=jnp.float32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (B, Sq, Hq, D), dtype),
            jax.random.normal(ks[1], (B, Skv, Hkv, D), dtype),
            jax.random.normal(ks[2], (B, Skv, Hkv, D), dtype))


@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 48)])
def test_fwd_matches_ref(causal, window):
    q, k, v = _qkv(2, 96, 96, 4, 2, 32)
    G = 4 // 2
    qg = q.transpose(0, 2, 1, 3).reshape(2, 2, G, 96, 32)
    out = ops.flash_attention_xla(qg, k.transpose(0, 2, 1, 3),
                                  v.transpose(0, 2, 1, 3), causal, window, 32)
    out = out.reshape(2, 4, 96, 32).transpose(0, 2, 1, 3)
    want = ref.attention_ref(q, k, v, causal=causal, sliding_window=window)
    assert jnp.abs(out - want).max() < 1e-4


@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 32)])
def test_grads_match_ref(causal, window):
    q, k, v = _qkv(1, 64, 64, 2, 1, 16)

    def loss_flash(q, k, v):
        G = 2
        qg = q.transpose(0, 2, 1, 3).reshape(1, 1, G, 64, 16)
        out = ops.flash_attention_xla(qg, k.transpose(0, 2, 1, 3),
                                      v.transpose(0, 2, 1, 3),
                                      causal, window, 16)
        return (out ** 2).sum()

    def loss_ref(q, k, v):
        return (ref.attention_ref(q, k, v, causal=causal,
                                  sliding_window=window)
                .astype(jnp.float32) ** 2).sum()

    g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        assert jnp.abs(a - b).max() < 2e-3, jnp.abs(a - b).max()


def test_chunk_size_independence():
    q, k, v = _qkv(1, 128, 128, 2, 2, 32)
    qg = q.transpose(0, 2, 1, 3).reshape(1, 2, 1, 128, 32)
    outs = [ops.flash_attention_xla(qg, k.transpose(0, 2, 1, 3),
                                    v.transpose(0, 2, 1, 3), True, 0, c)
            for c in (16, 32, 128)]
    for o in outs[1:]:
        assert jnp.allclose(o, outs[0], atol=1e-5)


def test_dispatcher_paths_agree():
    """ops.attention small-path (ref) vs large-path (chunked) agree."""
    q, k, v = _qkv(1, 1030, 1030, 2, 1, 16)   # just over the 1024 threshold
    big = ops.attention(q, k, v, causal=True)
    small = ref.attention_ref(q, k, v, causal=True)
    assert jnp.abs(big - small).max() < 1e-4


def test_decode_partial_stats_combine():
    """Sequence-sharded partial softmax recombines to the full result."""
    B, S, Hkv, D, Hq = 2, 64, 2, 16, 4
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, 1, Hq, D))
    ck = jax.random.normal(ks[1], (B, S, Hkv, D))
    cv = jax.random.normal(ks[2], (B, S, Hkv, D))
    pos = S - 1
    want = ref.decode_attention_ref(q, ck, cv, pos)
    # two shards over the sequence
    halves = [(ck[:, :32], cv[:, :32], jnp.arange(32) <= pos),
              (ck[:, 32:], cv[:, 32:], (jnp.arange(32) + 32) <= pos)]
    accs, ms, ls = zip(*[
        ops.decode_attention_partial(q, k_, v_,
                                     jnp.broadcast_to(val, (B, 32)))
        for k_, v_, val in halves])
    m = jnp.maximum(ms[0], ms[1])
    l = ls[0] * jnp.exp(ms[0] - m) + ls[1] * jnp.exp(ms[1] - m)
    acc = accs[0] * jnp.exp(ms[0] - m)[..., None] \
        + accs[1] * jnp.exp(ms[1] - m)[..., None]
    out = (acc / l[..., None]).reshape(B, 1, Hq, D)
    assert jnp.abs(out - want).max() < 1e-5
