"""End-to-end driver entry points (serve, workloads, analytic CLI paths)."""
import numpy as np

from repro.configs import get_config, reduced_config
from repro.core import workloads as W
from repro.core.sim import SimParams


def test_serve_driver_completes():
    from repro.launch.serve import serve
    cfg = reduced_config(get_config("olmo_1b"))
    out = serve(cfg, n_requests=8, clusters=2, groups_per_cluster=2,
                max_new=4, verbose=lambda *a, **k: None)
    assert out["finished"] == 8
    assert out["imbalance"] < 1.5


def test_workload_offered_load_sane():
    p = SimParams(m=256, k=16, n_childs=100)
    rho = W.offered_load(p, 14_000.0)
    assert 0.5 < rho < 1.0      # calibrated near-saturation, stable


def test_independent_tasks_shapes():
    p = SimParams(m=64, k=8, n_childs=32, max_apps=16)
    arr, gmns, lens = W.independent_tasks(p, n_apps=3)
    assert arr.shape == (16,) and lens.shape == (16, 32)
    assert (arr[:3] < 1e17).all() and (arr[3:] > 1e17).all()
    assert (gmns[:3] < 8).all()


def test_interference_respects_active_fraction():
    p = SimParams(m=64, k=4, n_childs=16, max_apps=256)
    arr, _, _ = W.interference(p, sim_len=1e6, active_frac=0.5, seed=0)
    finite = arr[arr < 1e17]
    assert finite.max() <= 0.6 * 1e6


def test_bursty_mmpp_workload_sane():
    p = SimParams(m=16, k=4, n_childs=16, max_apps=64)
    arr, gmns, lens = W.bursty(p, sim_len=1e6, seed=0)
    finite = arr[arr < 1e17]
    assert len(finite) > 0
    assert (np.diff(finite) >= 0).all()          # arrivals sorted
    assert finite.max() <= 0.9 * 1e6
    assert (gmns[: len(finite)] < 4).all()
    assert lens.shape == (64, 16)


def test_hotspot_workload_skews_to_hot_gmn():
    p = SimParams(m=16, k=4, n_childs=16, max_apps=256)
    arr, gmns, _ = W.hotspot(p, sim_len=1e7, hot_frac=0.8, hot_gmn=2,
                             seed=1)
    n = int((arr < 1e17).sum())
    assert n > 50
    frac = float((gmns[:n] == 2).mean())
    assert 0.7 < frac <= 1.0                     # ~hot_frac + uniform share


def test_heavy_tail_lengths_capped_and_skewed():
    p = SimParams(m=16, k=4, n_childs=64, max_apps=32)
    rng = np.random.default_rng(0)
    lens = W.heavy_tail_lengths(p, rng)
    assert lens.shape == (32, 64)
    assert lens.max() <= 8 * W.MAX_LEN + 1e-3
    assert np.median(lens) < lens.mean()         # right-skewed
    arr, gmns, lens2 = W.bursty(p, sim_len=5e5, seed=3,
                                length_dist="pareto")
    assert lens2.max() > W.MAX_LEN               # tail exceeds uniform cap


def test_fleet_one_group_degenerate():
    """k=1, 1 group: everything lands there; still completes."""
    from repro.serving.engine import FleetSim, Request
    fleet = FleetSim(k=1, groups_per_cluster=1, dn_th=4)
    for i in range(8):
        fleet.submit(Request(sort_key=float(i), rid=i, max_new=4))
    while fleet.active:
        fleet.tick()
    assert len(fleet.finished) == 8
    assert fleet.beacons_tx == 0         # k=1 never broadcasts (paper Sec 4.2)
