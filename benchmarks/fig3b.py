"""Fig 3b: transmitted status beacons vs threshold dn_th for several k.

Paper claim: at dn_th=4, k=32 transmits ~1.37x the beacons of k=16; a
coarser threshold suppresses synchronization traffic."""
from __future__ import annotations

import numpy as np

from repro.core import workloads as W
from repro.core.sim import SimParams, run as sim_run

from benchmarks.common import csv_row, save, timed

KS = (8, 16, 32, 64)
THRESHOLDS = (1, 2, 4, 8, 16, 32)


def run(verbose: bool = True, ks=KS, thresholds=THRESHOLDS,
        sim_len: float = 4e6, seed: int = 1) -> dict:
    curves = {}
    t_total = 0.0
    for k in ks:
        row = []
        for th in thresholds:
            p = SimParams(m=256, k=k, n_childs=100, dn_th=th,
                          max_apps=512, queue_cap=2048)
            arr, gmns, lens = W.interference(p, sim_len=sim_len, seed=seed)
            st, dt = timed(sim_run, p, arr, gmns, lens, sim_len)
            t_total += dt
            row.append(int(st["beacons_tx"]))
        curves[str(k)] = {"dn_th": list(thresholds), "beacons_tx": row}

    i4 = list(thresholds).index(4)
    ratio = (curves["32"]["beacons_tx"][i4] / curves["16"]["beacons_tx"][i4]
             if "32" in curves and "16" in curves else None)
    monotone = all(
        all(c["beacons_tx"][i] >= c["beacons_tx"][i + 1]
            for i in range(len(thresholds) - 1))
        for c in curves.values())
    payload = {
        "curves": curves,
        "ratio_k32_over_k16_at_th4": float(ratio) if ratio else None,
        "paper_claim": {"ratio_k32_over_k16_at_th4": 1.37,
                        "beacons_decrease_with_threshold": True},
        "claim_ratio_band": ratio is not None and 1.1 <= ratio <= 1.7,
        "claim_monotone": monotone,
    }
    save("fig3b", payload)
    if verbose:
        csv_row("fig3b_beacons", t_total * 1e6,
                f"k32/k16@th4={ratio:.2f}|monotone={monotone}")
    return payload


if __name__ == "__main__":
    run()
