"""Fig 3b: transmitted status beacons vs threshold dn_th for several k.

Paper claim: at dn_th=4, k=32 transmits ~1.37x the beacons of k=16; a
coarser threshold suppresses synchronization traffic.

Runs as ONE declarative experiment (core/experiment.py): the cluster
counts are the static shape axis, the thresholds the traced knob axis —
the planner compiles exactly one XLA program per k and the whole
threshold row rides the traced axis for free."""
from __future__ import annotations

from repro.core.experiment import ExperimentSpec, WorkloadSpec
from repro.core.sim import SimParams

from benchmarks.common import csv_row, save, timed

KS = (8, 16, 32, 64)
THRESHOLDS = (1, 2, 4, 8, 16, 32)


def run(verbose: bool = True, ks=KS, thresholds=THRESHOLDS,
        sim_len: float = 4e6, seed: int = 1) -> dict:
    spec = ExperimentSpec(
        base=SimParams(m=256, n_childs=100, max_apps=512, queue_cap=2048),
        shapes=tuple(ks),
        knobs={"dn_th": thresholds},
        workloads=(WorkloadSpec("interference", seeds=(seed,)),),
        sim_len=sim_len)
    frame, t_total = timed(spec.run)

    curves = {str(k): {"dn_th": list(thresholds),
                       "beacons_tx": frame.beacons_tx(k=k).tolist()}
              for k in ks}
    n_compiles = frame.compiles

    i4 = list(thresholds).index(4)
    ratio = (curves["32"]["beacons_tx"][i4] / curves["16"]["beacons_tx"][i4]
             if "32" in curves and "16" in curves else None)
    monotone = all(
        all(c["beacons_tx"][i] >= c["beacons_tx"][i + 1]
            for i in range(len(thresholds) - 1))
        for c in curves.values())
    payload = {
        "curves": curves,
        "ratio_k32_over_k16_at_th4": float(ratio) if ratio else None,
        "paper_claim": {"ratio_k32_over_k16_at_th4": 1.37,
                        "beacons_decrease_with_threshold": True},
        "claim_ratio_band": ratio is not None and 1.1 <= ratio <= 1.7,
        "claim_monotone": monotone,
        "n_compiles": n_compiles,
        "compile_once_per_shape": n_compiles <= len(ks),
    }
    save("fig3b", payload, spec=spec)
    if verbose:
        r = f"{ratio:.2f}" if ratio else "n/a"
        csv_row("fig3b_beacons", t_total * 1e6,
                f"k32/k16@th4={r}|monotone={monotone}"
                f"|compiles={n_compiles}")
    return payload


if __name__ == "__main__":
    run()
