"""Fig 2b: measured (TLM-simulated) speedup with recursive startup.

m=256 PEs, n=256 childs, sweeping k and the selection-delay coefficient
c_s; compared against the analytic projection (Fig 2a).

Runs as ONE declarative experiment (core/experiment.py): k is the
static shape axis, c_s the traced knob axis — 9 XLA programs total
instead of the 27 per-config runs the hand-rolled loop paid."""
from __future__ import annotations

import numpy as np

from repro.core import analytic as A
from repro.core.experiment import ExperimentSpec, WorkloadSpec
from repro.core.sim import SimParams

from benchmarks.common import csv_row, save, timed

KS = (1, 2, 4, 8, 16, 32, 64, 128, 256)


def run(verbose: bool = True, ks=KS, c_s_values=(1.0, 8.0, 64.0)) -> dict:
    spec = ExperimentSpec(
        base=SimParams(m=256, n_childs=256, max_apps=4, queue_cap=1024),
        shapes=tuple(ks),
        knobs={"c_s": c_s_values},
        workloads=(WorkloadSpec.make("independent", seeds=(0,), n_apps=1),),
        sim_len=1e7)
    frame, t_total = timed(spec.run)

    curves = {}
    for cs in c_s_values:
        row = [float(frame.speedup(k=k, c_s=cs)[0]) for k in ks]
        curves[str(cs)] = {"k": list(ks), "speedup": row}
    # compare to analytic at c_s=8
    ana = A.speedup(256, 256, np.array(ks),
                    A.TimingParams(c_s=8.0)).tolist()
    mid = curves.get("8.0", list(curves.values())[0])
    rel_err = float(np.mean(np.abs(
        (np.array(mid["speedup"]) - np.array(ana)) / np.array(ana))))
    payload = {"curves": curves, "analytic_cs8": ana,
               "mean_rel_err_vs_analytic": rel_err,
               "paper_claim": "measured fits analytic; optimum at 32-64 nodes",
               "fit_ok": rel_err < 0.25,
               "n_compiles": frame.compiles}
    save("fig2b", payload, spec=spec)
    if verbose:
        csv_row("fig2b_sim", t_total * 1e6,
                f"rel_err_vs_analytic={rel_err:.3f}|fit_ok={payload['fit_ok']}")
    return payload


if __name__ == "__main__":
    run()
