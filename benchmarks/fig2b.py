"""Fig 2b: measured (TLM-simulated) speedup with recursive startup.

m=256 PEs, n=256 childs, sweeping k and the selection-delay coefficient
c_s; compared against the analytic projection (Fig 2a)."""
from __future__ import annotations

import numpy as np

from repro.core import analytic as A
from repro.core import workloads as W
from repro.core.sim import SimParams, run as sim_run, speedup

from benchmarks.common import csv_row, save, timed

KS = (1, 2, 4, 8, 16, 32, 64, 128, 256)


def run(verbose: bool = True, ks=KS, c_s_values=(1.0, 8.0, 64.0)) -> dict:
    curves = {}
    t_total = 0.0
    for cs in c_s_values:
        row = []
        for k in ks:
            p = SimParams(m=256, k=k, n_childs=256, c_s=cs,
                          max_apps=4, queue_cap=1024)
            arr, gmns, lens = W.independent_tasks(p, n_apps=1)
            st, dt = timed(sim_run, p, arr, gmns, lens, 1e7)
            t_total += dt
            s, _ = speedup(st, arr, lens)
            row.append(s)
        curves[str(cs)] = {"k": list(ks), "speedup": row}
    # compare to analytic at c_s=8
    ana = A.speedup(256, 256, np.array(KS),
                    A.TimingParams(c_s=8.0)).tolist()
    mid = curves.get("8.0", list(curves.values())[0])
    rel_err = float(np.mean(np.abs(
        (np.array(mid["speedup"]) - np.array(ana)) / np.array(ana))))
    payload = {"curves": curves, "analytic_cs8": ana,
               "mean_rel_err_vs_analytic": rel_err,
               "paper_claim": "measured fits analytic; optimum at 32-64 nodes",
               "fit_ok": rel_err < 0.25}
    save("fig2b", payload)
    if verbose:
        csv_row("fig2b_sim", t_total * 1e6,
                f"rel_err_vs_analytic={rel_err:.3f}|fit_ok={payload['fit_ok']}")
    return payload


if __name__ == "__main__":
    run()
