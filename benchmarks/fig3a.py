"""Fig 3a: application-interference speedup vs beacon threshold dn_th,
for several cluster counts k (m=256, n=100 per app, Poisson lambda=7999).

Runs as ONE declarative experiment (core/experiment.py): k is the
static shape axis, (dn_th x seed) the traced grid — one XLA program per
k, everything else free."""
from __future__ import annotations

import numpy as np

from repro.core.experiment import ExperimentSpec, WorkloadSpec
from repro.core.sim import SimParams

from benchmarks.common import csv_row, save, timed

KS = (1, 8, 16, 32, 256)
THRESHOLDS = (1, 2, 4, 8, 16, 32)


def run(verbose: bool = True, ks=KS, thresholds=THRESHOLDS,
        sim_len: float = 4e6, seeds=(1, 2)) -> dict:
    spec = ExperimentSpec(
        base=SimParams(m=256, n_childs=100, max_apps=512, queue_cap=2048),
        shapes=tuple(ks),
        knobs={"dn_th": thresholds},
        workloads=(WorkloadSpec("interference", seeds=seeds),),
        sim_len=sim_len)
    frame, t_total = timed(spec.run)

    curves = {}
    for k in ks:
        # (B*S,) -> (B, S): knob-major, seed-minor point order
        row = frame.speedup(k=k).reshape(len(thresholds),
                                         len(seeds)).mean(axis=1)
        curves[str(k)] = {"dn_th": list(thresholds),
                          "speedup": [float(v) for v in row]}
    n_compiles = frame.compiles

    s1 = np.mean(curves["1"]["speedup"]) if "1" in curves else None
    s16_th4 = (curves["16"]["speedup"][list(thresholds).index(4)]
               if "16" in curves else None)
    s256 = np.mean(curves["256"]["speedup"]) if "256" in curves else None
    improvement_16 = float(s16_th4 / s1) if s1 and s16_th4 else None
    improvement_256 = float(s256 / s1) if s1 and s256 else None
    # robustness: clustered speedup stays flat while dn_th < m/k
    robust = True
    if "16" in curves:
        r = curves["16"]["speedup"]
        small = [v for v, t in zip(r, thresholds) if t < 256 // 16]
        robust = (max(small) - min(small)) / max(small) < 0.2
    payload = {
        "curves": curves,
        "improvement_k16_vs_k1": improvement_16,
        "improvement_k256_vs_k1": improvement_256,
        "paper_claim": {"k16_th4_vs_k1": 2.8, "k256_vs_k1": 1.6,
                        "robust_below_pes_per_cluster": True},
        "claim_k16_band": improvement_16 is not None
                          and 2.0 <= improvement_16 <= 3.6,
        "claim_robust": robust,
        "n_compiles": n_compiles,
        "compile_once_per_shape": n_compiles <= len(ks),
    }
    save("fig3a", payload, spec=spec)
    if verbose:
        i16 = f"{improvement_16:.2f}" if improvement_16 else "n/a"
        i256 = f"{improvement_256:.2f}" if improvement_256 else "n/a"
        csv_row("fig3a_interference", t_total * 1e6,
                f"k16/k1={i16}|k256/k1={i256}"
                f"|robust={robust}|compiles={n_compiles}")
    return payload


if __name__ == "__main__":
    run()
