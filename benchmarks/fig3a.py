"""Fig 3a: application-interference speedup vs beacon threshold dn_th,
for several cluster counts k (m=256, n=100 per app, Poisson lambda=7999)."""
from __future__ import annotations

import numpy as np

from repro.core import workloads as W
from repro.core.sim import SimParams, run as sim_run, speedup

from benchmarks.common import csv_row, save, timed

KS = (1, 8, 16, 32, 256)
THRESHOLDS = (1, 2, 4, 8, 16, 32)


def run(verbose: bool = True, ks=KS, thresholds=THRESHOLDS,
        sim_len: float = 4e6, seeds=(1, 2)) -> dict:
    curves = {}
    t_total = 0.0
    for k in ks:
        row = []
        for th in thresholds:
            vals = []
            for seed in seeds:
                p = SimParams(m=256, k=k, n_childs=100, dn_th=th,
                              max_apps=512, queue_cap=2048)
                arr, gmns, lens = W.interference(p, sim_len=sim_len, seed=seed)
                st, dt = timed(sim_run, p, arr, gmns, lens, sim_len)
                t_total += dt
                s, _ = speedup(st, arr, lens)
                vals.append(s)
            row.append(float(np.mean(vals)))
        curves[str(k)] = {"dn_th": list(thresholds), "speedup": row}

    s1 = np.mean(curves["1"]["speedup"]) if "1" in curves else None
    s16_th4 = (curves["16"]["speedup"][list(thresholds).index(4)]
               if "16" in curves else None)
    s256 = np.mean(curves["256"]["speedup"]) if "256" in curves else None
    improvement_16 = float(s16_th4 / s1) if s1 and s16_th4 else None
    improvement_256 = float(s256 / s1) if s1 and s256 else None
    # robustness: clustered speedup stays flat while dn_th < m/k
    robust = True
    if "16" in curves:
        r = curves["16"]["speedup"]
        small = [v for v, t in zip(r, thresholds) if t < 256 // 16]
        robust = (max(small) - min(small)) / max(small) < 0.2
    payload = {
        "curves": curves,
        "improvement_k16_vs_k1": improvement_16,
        "improvement_k256_vs_k1": improvement_256,
        "paper_claim": {"k16_th4_vs_k1": 2.8, "k256_vs_k1": 1.6,
                        "robust_below_pes_per_cluster": True},
        "claim_k16_band": improvement_16 is not None
                          and 2.0 <= improvement_16 <= 3.6,
        "claim_robust": robust,
    }
    save("fig3a", payload)
    if verbose:
        csv_row("fig3a_interference", t_total * 1e6,
                f"k16/k1={improvement_16:.2f}|k256/k1={improvement_256:.2f}"
                f"|robust={robust}")
    return payload


if __name__ == "__main__":
    run()
