"""Software analogue of paper Table 4 (GMN area/clock): the per-decision
cost of the two-stage mapper in this framework's scheduler, vs a flat
argmin over all m units, across cluster counts k.

Also reports decisions/second for the batched kernel path (the serving
engine's hot loop)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

from benchmarks.common import csv_row, save


def _bench(fn, *args, iters=20):
    fn(*args)                                 # compile
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters


def run(verbose: bool = True, m: int = 256, n_tasks: int = 100) -> dict:
    rows = {}
    costs = jnp.ones((n_tasks,), jnp.float32)

    @jax.jit
    def flat_assign(loads_flat, costs):
        def step(loads, c):
            i = jnp.argmin(loads)
            return loads.at[i].add(c), i
        return jax.lax.scan(step, loads_flat, costs)

    flat = jnp.zeros((m,), jnp.float32)
    t_flat = _bench(flat_assign, flat, costs)

    for k in (1, 8, 16, 32, 256):
        loads = jnp.zeros((k, m // k), jnp.float32)
        t = _bench(lambda l=loads: ops.assign_tasks(l, costs))
        rows[str(k)] = {"us_per_batch": t * 1e6,
                        "us_per_decision": t * 1e6 / n_tasks}
    payload = {
        "two_stage": rows,
        "flat_argmin_us_per_batch": t_flat * 1e6,
        "note": "paper Table 4 is 65nm silicon area (out of scope); this is "
                "the software scheduler's decision latency on this host",
    }
    save("scheduler_overhead", payload)
    if verbose:
        csv_row("scheduler_overhead",
                rows["16"]["us_per_batch"],
                f"us_per_decision_k16={rows['16']['us_per_decision']:.2f}")
    return payload


if __name__ == "__main__":
    run()
