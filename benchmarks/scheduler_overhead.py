"""Software analogue of paper Table 4 (GMN area/clock): the per-decision
cost of the two-stage mapper in this framework's scheduler, vs a flat
argmin over all m units, across cluster counts k.

Also reports the TLM sweep engine's throughput (events/s across a batch
of knob configs in one compiled program) — the batched path every
design-space benchmark (fig3a/fig3b/table5/baseline_compare) rides on."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sweep as SW
from repro.core.experiment import ExperimentSpec, WorkloadSpec
from repro.core.sim import SimParams
from repro.kernels import ops

from benchmarks.common import csv_row, save


def _bench(fn, *args, iters=20):
    fn(*args)                                 # compile
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters


def _bench_sweep(thresholds=(1, 2, 4, 8), iters=3):
    """Events/second of the sweep engine (both single-device dispatch
    strategies) on a small interference grid.

    The grid is *defined* declaratively (the spec is the payload's
    provenance), but the timed loop drives the underlying engine with
    prebuilt inputs — exactly what this benchmark has always measured —
    so the BENCH trajectory stays comparable: workload generation,
    planning and ResultFrame construction are not on the clock."""
    spec = ExperimentSpec(
        base=SimParams(m=64, k=8, n_childs=32, max_apps=64, queue_cap=1024),
        knobs={"dn_th": thresholds},
        workloads=(WorkloadSpec("interference", seeds=(0,)),),
        sim_len=3e5)
    combo = spec.plan().combos[0]
    _, wl = spec.workloads[0].build(combo.shape, spec.sim_len)
    out = {"configs": len(thresholds), "spec": spec.to_dict()}
    for mode in ("seq", "vmap"):
        st = jax.block_until_ready(
            SW.sweep(combo.shape, spec.knobs, wl, spec.sim_len, mode=mode,
                     policy=combo.policy, topology=combo.topology))
        t0 = time.time()
        for _ in range(iters):
            st = jax.block_until_ready(
                SW.sweep(combo.shape, spec.knobs, wl, spec.sim_len,
                         mode=mode, policy=combo.policy,
                         topology=combo.topology))
        dt = (time.time() - t0) / iters
        events = int(np.asarray(st["events_processed"]).sum())
        out[mode] = {"events_per_batch": events,
                     "sweep_s": dt,
                     "events_per_sec": events / dt,
                     "us_per_event": dt / events * 1e6}
    return out


def run(verbose: bool = True, m: int = 256, n_tasks: int = 100) -> dict:
    rows = {}
    costs = jnp.ones((n_tasks,), jnp.float32)

    @jax.jit
    def flat_assign(loads_flat, costs):
        def step(loads, c):
            i = jnp.argmin(loads)
            return loads.at[i].add(c), i
        return jax.lax.scan(step, loads_flat, costs)

    flat = jnp.zeros((m,), jnp.float32)
    t_flat = _bench(flat_assign, flat, costs)

    for k in (1, 8, 16, 32, 256):
        loads = jnp.zeros((k, m // k), jnp.float32)
        t = _bench(lambda l=loads: ops.assign_tasks(l, costs))
        rows[str(k)] = {"us_per_batch": t * 1e6,
                        "us_per_decision": t * 1e6 / n_tasks}
    sweep_engine = _bench_sweep()
    payload = {
        "two_stage": rows,
        "flat_argmin_us_per_batch": t_flat * 1e6,
        "sweep_engine": sweep_engine,
        "note": "paper Table 4 is 65nm silicon area (out of scope); this is "
                "the software scheduler's decision latency on this host",
    }
    save("scheduler_overhead", payload, spec=sweep_engine.pop("spec"))
    if verbose:
        csv_row("scheduler_overhead",
                rows["16"]["us_per_batch"],
                f"us_per_decision_k16={rows['16']['us_per_decision']:.2f}"
                f"|sweep_ev_per_s="
                f"{sweep_engine['seq']['events_per_sec']:.0f}")
    return payload


if __name__ == "__main__":
    run()
