"""Fault frontier: which (k, policy, topology) points of the clustered
task manager degrade gracefully when the management fabric fails
(core/faults.py, DESIGN.md §13).

The paper evaluates the manager on a static fabric; this benchmark
stresses the same design space under fault injection — seeded Poisson
link failures, a scheduled partition-and-heal, and GMN churn with
hot-spare takeover — and reports, per (k, policy, topology, fault)
point, the availability counters (``msgs_lost`` / ``reroutes`` /
``downtime``) beside the usual management-overhead metrics.  The whole
grid is ONE declarative experiment riding the ``faults`` axis of
``ExperimentSpec``; fault schedules are traced, so the entire fault
axis adds exactly one XLA program per static group and a *second* spec
with fresh fault seeds compiles nothing at all (the no-recompile claim
below).

Every payload gates these claims:

  claim_nofault_bitwise_anchor   the PR-2 frozen golden grid reproduces
                                 bitwise (same beacons_tx, same app_done
                                 sha256) when run WITH the fault
                                 machinery compiled in and zero events —
                                 the fault subsystem is invisible until
                                 a fault actually fires.
  claim_msgs_lost_under_faults   lossy scenarios actually lose beacons
                                 (msgs_lost > 0 on every partition row).
  claim_conservation             beacons_rx + msgs_lost ==
                                 (k-1) * beacons_tx on every row — no
                                 message is double-counted or leaks.
  claim_all_apps_complete        the control plane is reliable: every
                                 arrived application completes under
                                 every fault scenario (work re-homes and
                                 detours, it is never lost).
  claim_one_program_per_group    compiles == expected_programs for the
                                 grid (fault axis adds one program per
                                 group, not one per scenario).
  claim_fault_grid_no_recompile  a second spec with different fault
                                 seeds compiles zero new programs.
  claim_graceful_degradation     mean response under every fault
                                 scenario stays within GRACEFUL_FACTOR
                                 of the same point's no-fault response.
  claim_downtime_accounted       partition rows carry exactly the
                                 scheduled outage in ``downtime``.

plus ``determinism_digest`` — a sha256 over the deterministic row
fields (wall-clock excluded) that the CI fault-smoke job computes twice
with the same seeds and diffs (schema v5, benchmarks/README.md).

Usage:  PYTHONPATH=src python -m benchmarks.fault_frontier \
            [--grid tiny|default]
"""
from __future__ import annotations

import argparse
import hashlib

import numpy as np

from repro.core import sweep as SW
from repro.core import workloads as W
from repro.core.experiment import ExperimentSpec, WorkloadSpec
from repro.core.faults import FaultSpec
from repro.core.sim import SimParams

from benchmarks.common import (csv_row, determinism_digest, save, timed,
                               topology_meta)

# The PR-2 frozen goldens (tests/test_sweep.py): the (dn_th x seed) grid
# at m=16/k=4 captured at commit 0872ddc.  The fault-aware program with
# an empty schedule must keep reproducing them bitwise.
_GOLDEN_BEACONS = [[600, 600], [351, 360], [202, 232], [72, 78]]
_GOLDEN_APP_DONE_SHA = \
    "72576e858be248d11e21055618ff6a1aba89ebd7f7f4ea3419d9384b59cd3efa"

# Mean response under faults may exceed the no-fault response by at most
# this factor for the point to count as degrading gracefully.  The
# reliable control plane (detours + takeover, never loss) keeps the
# measured worst case well under 2x on both tiers; see results JSON.
GRACEFUL_FACTOR = 2.0

GRIDS = {
    # CI smoke: the full claim set in about a minute
    "tiny": dict(m=16, ks=(2, 4), n_childs=16, max_apps=32, queue_cap=512,
                 policies=(("min_search", "threshold"),
                           ("round_robin", "periodic")),
                 topologies=("hier_tree", "mesh2d"),
                 dn_th=2, sim_len=2e5, seeds=(0,),
                 poisson_rate=4e-4, poisson_repair=2e4,
                 poisson_seeds=(0,), churn_rate=4e-5, churn_repair=3e4),
    "default": dict(m=16, ks=(2, 4, 8, 16), n_childs=16, max_apps=64,
                    queue_cap=2048,
                    policies=(("min_search", "threshold"),
                              ("round_robin", "periodic")),
                    topologies=("hier_tree", "mesh2d"),
                    dn_th=2, sim_len=4e5, seeds=(0, 1),
                    poisson_rate=4e-4, poisson_repair=3e4,
                    poisson_seeds=(0, 1), churn_rate=2e-5,
                    churn_repair=5e4),
}


def _fault_axis(g, seed_offset=0):
    """The fault-scenario axis: the zero-event anchor, a seed grid of
    Poisson link failures, one partition-and-heal, and GMN churn.

    ``seed_offset`` shifts every stochastic generator's seed while
    keeping the axis structure — and therefore every padded schedule
    capacity — identical, which is what the no-recompile claim reuses."""
    t_down, t_heal = 0.3 * g["sim_len"], 0.6 * g["sim_len"]
    axis = [FaultSpec.none()]
    axis += [FaultSpec.poisson_links(rate=g["poisson_rate"],
                                     repair=g["poisson_repair"],
                                     seed=s + seed_offset,
                                     name=f"poisson_s{s + seed_offset}")
             for s in g["poisson_seeds"]]
    axis.append(FaultSpec.partition(t_down=t_down, t_heal=t_heal,
                                    name="partition"))
    axis.append(FaultSpec.gmn_churn(rate=g["churn_rate"],
                                    repair=g["churn_repair"],
                                    seed=seed_offset))
    return tuple(axis), (t_down, t_heal)


def _golden_anchor() -> bool:
    """The PR-2 golden grid through the fault-aware program (empty
    schedule): bitwise equality is the subsystem's no-fault contract."""
    p = SimParams(m=16, k=4, n_childs=16, max_apps=32, queue_cap=512)
    wl = W.interference_batch(p, seeds=(0, 1), sim_len=3e5)
    st = SW.sweep(p.shape, SW.knob_batch(dn_th=(1, 2, 4, 8)), wl, 3e5,
                  faults=FaultSpec.none())
    done = np.asarray(st["app_done"], np.float32)
    return (np.asarray(st["beacons_tx"]).tolist() == _GOLDEN_BEACONS
            and hashlib.sha256(done.tobytes()).hexdigest()
            == _GOLDEN_APP_DONE_SHA
            and int(np.asarray(st["msgs_lost"]).sum()) == 0)


def run(verbose: bool = True, grid: str = "tiny") -> dict:
    g = GRIDS[grid]
    faults, (t_down, t_heal) = _fault_axis(g)
    workload = WorkloadSpec.make("interference", seeds=g["seeds"])
    base = SimParams(m=g["m"], n_childs=g["n_childs"],
                     max_apps=g["max_apps"], queue_cap=g["queue_cap"])

    spec = ExperimentSpec(
        base=base, shapes=g["ks"], policies=g["policies"],
        topologies=g["topologies"], knobs={"dn_th": g["dn_th"]},
        workloads=(workload,), faults=faults,
        sim_len=g["sim_len"], mode="seq")
    frame, t_total = timed(spec.run)

    fault_labels = [f.label for f in faults]
    faulty_labels = [l for l in fault_labels if l != "none"]
    rows = []
    complete_ok = True
    for gr in frame.groups:
        st = gr.state
        arr = np.asarray(st["app_arrive"])
        done = np.asarray(st["app_done"])
        complete_ok &= bool((done[arr < 1e17] < 1e17).all())
        k, topo = gr.combo.shape.k, gr.combo.topology.kind
        pol = gr.combo.policy.mapping
        sel = dict(k=k, topology=topo, mapping=pol, fault=gr.fault_label)
        rows.append({
            "k": k, "topology": topo, "mapping": pol,
            "fault": gr.fault_label,
            "mean_response": float(np.nanmean(frame.mean_response(**sel))),
            "beacons_tx": int(np.asarray(st["beacons_tx"]).sum()),
            "beacons_rx": int(np.asarray(st["beacons_rx"]).sum()),
            "msgs_lost": int(frame.msgs_lost(**sel).sum()),
            "reroutes": int(frame.reroutes(**sel).sum()),
            "downtime": float(frame.downtime(**sel).sum()),
            "dropped": int(np.asarray(st["dropped"]).sum()),
            "events": int(np.asarray(st["events_processed"]).sum()),
            "wall_s": float(gr.wall_s),
        })

    def point_rows(k, topo, pol):
        return {r["fault"]: r for r in rows
                if r["k"] == k and r["topology"] == topo
                and r["mapping"] == pol}

    # conservation per row (every grid fabric is non-ideal): each lane
    # obeys it individually, so the group-summed counters do too
    conservation = all(
        r["beacons_rx"] + r["msgs_lost"] == (r["k"] - 1) * r["beacons_tx"]
        for r in rows)
    lost_under_partition = all(r["msgs_lost"] > 0 for r in rows
                               if r["fault"] == "partition")
    lanes = len(g["seeds"])
    downtime_ok = all(
        r["downtime"] == _partition_links(r["k"]) * (t_heal - t_down) * lanes
        for r in rows if r["fault"] == "partition")

    # graceful degradation: response under every scenario vs the same
    # point's no-fault anchor
    degradation = []
    for k in g["ks"]:
        for topo in g["topologies"]:
            for pol, _ in g["policies"]:
                by_fault = point_rows(k, topo, pol)
                anchor = by_fault["none"]["mean_response"]
                worst = max(by_fault[l]["mean_response"]
                            for l in faulty_labels)
                degradation.append({
                    "k": k, "topology": topo, "mapping": pol,
                    "worst_over_none": float(worst / anchor)})
    worst_degradation = max(d["worst_over_none"] for d in degradation)

    # a second spec, every stochastic fault seed shifted, same axis
    # structure (so every per-k padded schedule capacity matches): the
    # fault-aware programs are already compiled, so zero new XLA programs
    reuse = ExperimentSpec(
        base=base, shapes=g["ks"], policies=g["policies"],
        topologies=g["topologies"], knobs={"dn_th": g["dn_th"]},
        workloads=(workload,), faults=_fault_axis(g, seed_offset=100)[0],
        sim_len=g["sim_len"], mode="seq")
    reuse_frame = reuse.run()

    anchor_ok = _golden_anchor()

    payload = {
        "grid": grid,
        "rows": rows,
        "degradation": degradation,
        "worst_degradation": float(worst_degradation),
        "graceful_factor": GRACEFUL_FACTOR,
        "fault_axis": [f.to_dict() for f in faults],
        "meta": topology_meta(topologies=list(g["topologies"]), grid=grid,
                              m=g["m"], ks=list(g["ks"])),
        "paper_claim": "the clustered manager's message-passing protocol "
                       "is analyzed on a static fabric (Sec 5.4); this "
                       "frontier extends the analysis to a faulty one",
        "n_compiles": frame.compiles,
        "expected_programs": frame.expected_programs,
        "claim_nofault_bitwise_anchor": bool(anchor_ok),
        "claim_msgs_lost_under_faults": bool(lost_under_partition),
        "claim_conservation": bool(conservation),
        "claim_all_apps_complete": bool(
            complete_ok and all(r["dropped"] == 0 for r in rows)),
        "claim_one_program_per_group": bool(
            frame.compiles == frame.expected_programs),
        "claim_fault_grid_no_recompile": bool(reuse_frame.compiles == 0),
        "claim_graceful_degradation": bool(
            worst_degradation <= GRACEFUL_FACTOR),
        "claim_downtime_accounted": bool(downtime_ok),
    }
    payload["determinism_digest"] = determinism_digest(rows)
    payload["claims_all_pass"] = all(
        v for key, v in payload.items() if key.startswith("claim_"))

    save("fault_frontier", payload, spec=spec)
    if verbose:
        csv_row("fault_frontier", t_total * 1e6,
                f"claims_all_pass={payload['claims_all_pass']}"
                f"|worst_degradation={worst_degradation:.3f}"
                f"|compiles={frame.compiles}/{frame.expected_programs}"
                f"|digest={payload['determinism_digest'][:12]}")
        for r in rows:
            print(f"  k={r['k']:3d} {r['topology']:>9} {r['mapping']:>11} "
                  f"{r['fault']:>12}: resp={r['mean_response']:.0f} "
                  f"lost={r['msgs_lost']:4d} reroutes={r['reroutes']:4d} "
                  f"downtime={r['downtime']:.3g}")
    return payload


def _partition_links(k: int) -> int:
    """Directed links crossing the default frac=0.5 cut of a k-fabric."""
    a = int(np.ceil(k * 0.5))
    return 2 * a * (k - a)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--grid", choices=sorted(GRIDS), default="tiny")
    args = ap.parse_args()
    run(grid=args.grid)
