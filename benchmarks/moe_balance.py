"""Beyond-paper: the paper's load metric applied to MoE expert dispatch.

Routes synthetic tokens through the DeepSeek-MoE router config and reports
expert-load imbalance + dropped-token fraction — the same 'summarized
workload' statistic the paper's beacons communicate, here measured on the
in-model task-mapping problem (see DESIGN.md §4)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.models import moe as MOE

from benchmarks.common import csv_row, save, timed


def run(verbose: bool = True) -> dict:
    cfg = reduced_config(get_config("deepseek_moe_16b"),
                         d_model=128, vocab_size=512)
    key = jax.random.PRNGKey(0)
    params = MOE.init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (8, 128, cfg.d_model))
    (out, aux), dt = timed(lambda: MOE.apply_moe(params, cfg, x))
    frac = np.asarray(aux["tokens_per_expert"])
    imbalance = float(frac.max() / max(frac.mean(), 1e-9))
    payload = {
        "n_experts": cfg.moe.n_experts,
        "top_k": cfg.moe.top_k,
        "max_over_mean_expert_load": imbalance,
        "dropped_frac": float(aux["dropped_frac"]),
        "load_balance_loss": float(aux["load_balance"]),
    }
    save("moe_balance", payload)
    if verbose:
        csv_row("moe_balance", dt * 1e6,
                f"imbalance={imbalance:.2f}|dropped={payload['dropped_frac']:.3f}")
    return payload


if __name__ == "__main__":
    run()
