"""Fig 2a: analytic speedup vs number of global nodes k (m=256, n=256)."""
from __future__ import annotations

from repro.core import analytic as A

from benchmarks.common import csv_row, save, timed


def run(verbose: bool = True) -> dict:
    out, dt = timed(A.fig2a, m=256, n=256, c_s_values=(1.0, 8.0, 64.0))
    best = {cs: out[cs]["k"][int(max(range(len(out[cs]["speedup"])),
                                     key=lambda i: out[cs]["speedup"][i]))]
            for cs in out}
    payload = {"curves": {str(k): v for k, v in out.items()},
               "optimal_k_by_cs": {str(k): v for k, v in best.items()},
               "paper_claim": "recursive startup favors 32-64 global nodes",
               "claim_holds": all(8 <= v <= 64 for v in best.values())}
    save("fig2a", payload)
    if verbose:
        csv_row("fig2a_analytic", dt * 1e6,
                f"optimal_k={best}|claim_8..64={payload['claim_holds']}")
    return payload


if __name__ == "__main__":
    run()
