"""Headline baseline comparison (paper Sec 5.4 + Table 5, Fig 4 workload):
clustered management (1 < k < m) vs centralized (k=1, Nexus++-like) vs
fully-distributed (k=m, Isonet-like), across stimulus arrival rates.

Metric: mean application response time under two-stream interference.
The paper's claim is that the clustered configuration reduces both the
computation overhead that saturates a centralized manager and the
communication/staleness overhead that penalizes a fully-distributed one,
so it wins on response time once the system is under load.

Runs as ONE declarative experiment (core/experiment.py): k is the
static shape axis; the (arrival-rate x seed) grid is one traced
workload lane axis — one XLA program per k."""
from __future__ import annotations

import numpy as np

from repro.core import workloads as W
from repro.core.experiment import ExperimentSpec, WorkloadSpec
from repro.core.sim import SimParams

from benchmarks.common import csv_row, save, timed

M = 256
K_CLUSTERED = 16
KS = (1, K_CLUSTERED, M)            # centralized / this work / distributed
PAIR_PERIODS = (20_000.0, 14_000.0, 10_000.0)   # ticks; lower = higher load
SEEDS = (1, 2)


def run(verbose: bool = True, ks=KS, pair_periods=PAIR_PERIODS,
        seeds=SEEDS, sim_len: float = 2e6) -> dict:
    spec = ExperimentSpec(
        base=SimParams(m=M, n_childs=100, max_apps=512, queue_cap=2048),
        shapes=tuple(ks),
        knobs={"dn_th": 4},
        workloads=(WorkloadSpec.make("interference", seeds=seeds,
                                     pair_periods=tuple(pair_periods)),),
        sim_len=sim_len)
    frame, t_total = timed(spec.run)

    rows = {}
    grid = (len(pair_periods), len(seeds))
    for k in ks:
        p = SimParams(m=M, k=k, n_childs=100, max_apps=512, queue_cap=2048)
        mr = frame.mean_response(k=k).reshape(grid).mean(axis=1)
        sp = frame.speedup(k=k).reshape(grid).mean(axis=1)
        rows[str(k)] = {
            "pair_period": list(pair_periods),
            "offered_load": [float(W.offered_load(p, pp))
                             for pp in pair_periods],
            "mean_response": [float(v) for v in mr],
            "speedup": [float(v) for v in sp],
        }
    mr_c = np.array(rows[str(K_CLUSTERED)]["mean_response"])
    mr_1 = np.array(rows["1"]["mean_response"])
    mr_m = np.array(rows[str(M)]["mean_response"])
    beats_centralized = (mr_c < mr_1).tolist()
    beats_distributed = (mr_c < mr_m).tolist()
    payload = {
        "rows": rows,
        "clustered_k": K_CLUSTERED,
        "beats_centralized_per_rate": beats_centralized,
        "beats_distributed_per_rate": beats_distributed,
        "claim_clustered_best": bool(all(beats_centralized)
                                     and all(beats_distributed)),
        "paper_claim": "clustered management reduces both computation "
                       "(vs k=1) and communication (vs k=m) overhead "
                       "(Sec 5.4, Table 5)",
    }
    save("baseline_compare", payload, spec=spec)
    if verbose:
        gain_1 = float((mr_1 / mr_c).mean())
        gain_m = float((mr_m / mr_c).mean())
        csv_row("baseline_compare", t_total * 1e6,
                f"resp_k1/k{K_CLUSTERED}={gain_1:.2f}"
                f"|resp_k{M}/k{K_CLUSTERED}={gain_m:.2f}"
                f"|clustered_best={payload['claim_clustered_best']}")
    return payload


if __name__ == "__main__":
    run()
