"""Render the roofline table (EXPERIMENTS.md §Roofline) from dry-run JSON.

    PYTHONPATH=src:. python -m benchmarks.roofline_report \\
        [--json results/dryrun_final.json] [--mesh 16x16]
"""
from __future__ import annotations

import argparse
import json


def render(path: str, mesh: str = "16x16") -> str:
    rows = json.load(open(path))
    ok = [r for r in rows if r.get("status") == "ok" and r["mesh"] == mesh]
    skipped = [r for r in rows if r.get("status") == "skipped"]
    out = []
    out.append(f"Mesh {mesh} — {len(ok)} cells (+{len(skipped)} documented "
               f"skips). Terms are per-chip seconds; bottleneck = max term.")
    out.append("")
    hdr = (f"| {'cell':36s} | mb | {'compute s':>9s} | {'memory s':>9s} | "
           f"{'collect s':>9s} | bound | roofl% | useful% | peak GB | fits |")
    out.append(hdr)
    out.append("|" + "-" * (len(hdr) - 2) + "|")
    for r in sorted(ok, key=lambda r: r["cell"]):
        out.append(
            f"| {r['cell']:36s} | {r.get('microbatches', 1):2d} "
            f"| {r['t_compute_s']:9.3f} | {r['t_memory_s']:9.3f} "
            f"| {r['t_collective_s']:9.3f} | {r['bottleneck'][:5]:5s} "
            f"| {100 * r['roofline_fraction']:6.2f} "
            f"| {100 * r['useful_flops_ratio']:7.1f} "
            f"| {r['peak_bytes_per_chip'] / 1e9:7.2f} "
            f"| {'yes' if r['fits_16gb'] else 'NO':4s} |")
    for r in skipped:
        out.append(f"| {r['cell']:36s} | SKIPPED: {r.get('reason','')} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="results/dryrun_final.json")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    print(render(args.json, args.mesh))


if __name__ == "__main__":
    main()
