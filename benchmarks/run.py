"""Benchmark runner: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and writes JSON payloads to
results/.  The roofline table (EXPERIMENTS.md §Roofline) comes from the
separate 512-device dry-run (python -m repro.launch.dryrun --all), which
must run in its own process because of XLA_FLAGS.
"""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import baseline_compare, fig2a, fig2b, fig3a, fig3b, table5
    from benchmarks import fault_frontier, moe_balance, scheduler_overhead
    from benchmarks import topology_frontier

    print("name,us_per_call,derived")
    ok = True
    fig2a.run()
    b = fig2b.run()
    ok &= b["fit_ok"]
    a = fig3a.run()
    ok &= a["claim_k16_band"]
    bb = fig3b.run()
    ok &= bb["claim_monotone"]
    ok &= bb["compile_once_per_shape"]
    t = table5.run()
    ok &= t["ordering_clustered_best"]
    c = baseline_compare.run()
    ok &= c["claim_clustered_best"]
    tf = topology_frontier.run(grid="tiny")
    ok &= tf["claim_clustered_lowest_total_mgmt_latency"]
    ok &= tf["claim_ideal_bitwise_vs_run"]
    ff = fault_frontier.run(grid="tiny")
    ok &= ff["claims_all_pass"]
    scheduler_overhead.run()
    moe_balance.run()
    print(f"# paper-claim checks {'PASS' if ok else 'FAIL'}")
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
