"""Topology frontier: the paper's centralized / clustered / distributed
comparison with the management-communication overhead broken out per
interconnect fabric (paper Sec 5.4 + Table 5; DESIGN.md §10).

``baseline_compare`` reproduces the response-time ordering; this
benchmark explains *why* by routing all management messages through the
explicit transport model (``core/transport.py``) and separating

  comm  — transport latency: sum of (delivery - ready) over every
          management message (task-starts, join-exits + forwards,
          per-receiver beacon deliveries),
  proc  — manager latency: GMN queueing + service for fork expansion,
          stage-2 decision batches, and barrier decrements.

The paper's claim decomposes cleanly: the centralized k=1 manager drowns
in ``proc`` (decision serialization) *and* in ``comm`` (one local bus
carries every task-start/join of m PEs); the fully-distributed k=m
configuration pays ``comm`` for the all-to-all beacon/spawn traffic; the
clustered configuration (1 < k < m) minimizes the total on the paper's
own ``hier_tree`` fabric.  Per-receiver beacon skew (``bcn_skew_*``)
is reported per topology — zero under ``ideal`` by construction,
strictly positive under the non-ideal fabrics (the heterogeneity that
feeds the ``staleness_weighted`` policy).

Usage:  PYTHONPATH=src python -m benchmarks.topology_frontier [--grid tiny]
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.core import sweep as SW
from repro.core import workloads as W
from repro.core.sim import SimParams
from repro.core.sim import run as sim_run
from repro.core.transport import TOPOLOGIES

from benchmarks.common import csv_row, save, timed, topology_meta

# The c_s knob is raised (uniformly across every configuration, so the
# comparison stays fair) to put the centralized manager into the paper's
# saturation regime at a scale the CPU sweep finishes in minutes: the
# decision stream then reserves the k=1 manager's single local bus ahead
# of the join-exit traffic exactly as at the paper's m=256/c_s=8 point.
GRIDS = {
    # CI smoke: all (k x topology) combos in well under two minutes
    "tiny": dict(m=16, ks=(1, 4, 16), n_childs=16, max_apps=64,
                 queue_cap={16: 2048}, default_queue_cap=1024,
                 c_s=256.0, sim_len=4e5, pair_periods=(33_000.0,),
                 seeds=(0,)),
    "default": dict(m=64, ks=(1, 8, 64), n_childs=50, max_apps=256,
                    queue_cap={64: 8192}, default_queue_cap=4096,
                    c_s=40.0, sim_len=2e6, pair_periods=(26_000.0,),
                    seeds=(1, 2)),
}


def run(verbose: bool = True, grid: str = "default",
        topologies=TOPOLOGIES) -> dict:
    g = GRIDS[grid]
    missing = {"ideal", "hier_tree"} - set(topologies)
    if missing:
        raise ValueError(f"the headline claims need the {sorted(missing)} "
                         "fabric(s) in `topologies`")
    m, clustered = g["m"], [k for k in g["ks"] if 1 < k < g["m"]][0]
    knobs = SW.knob_batch(dn_th=4, c_s=g["c_s"])
    rows = []
    t_total = 0.0
    for k in g["ks"]:
        p = SimParams(m=m, k=k, n_childs=g["n_childs"],
                      max_apps=g["max_apps"],
                      queue_cap=g["queue_cap"].get(k, g["default_queue_cap"]))
        wl = W.interference_grid(p, pair_periods=g["pair_periods"],
                                 seeds=g["seeds"], sim_len=g["sim_len"])
        # with a single cluster no inter-GMN traffic exists, so every
        # fabric produces identical results: run once, replicate the row
        k_topos = topologies if k > 1 else topologies[:1]
        k_rows = []
        for topo in k_topos:
            # np.asarray inside timed(): sweep returns unrealized async
            # jax arrays, so timing must include materialization
            st, dt = timed(lambda: jax.tree.map(
                np.asarray, SW.sweep(p.shape, knobs, wl, g["sim_len"],
                                     policy=SW.SimPolicy(), topology=topo)))
            t_total += dt
            comm = SW.mgmt_latency(st)[0]             # (S,)
            proc = SW.mgmt_proc(st)[0]
            msgs = SW.mgmt_msgs(st)[0]
            skew_max = np.asarray(st["bcn_skew_max"], np.float64)[0]
            k_rows.append({
                "k": k, "topology": topo,
                "mean_response": float(np.nanmean(SW.mean_response(st)[0])),
                "beacons_tx": int(SW.beacons(st)[0].sum()),
                "beacons_rx": int(SW.beacons_rx(st)[0].sum()),
                "mgmt_msgs": int(msgs.sum()),
                "comm_latency": float(comm.sum()),
                "proc_latency": float(proc.sum()),
                "total_mgmt_latency": float((comm + proc).sum()),
                "comm_per_msg": float(comm.sum() / max(msgs.sum(), 1)),
                "bcn_skew_max": float(skew_max.max()),
                "dropped": int(np.asarray(st["dropped"])[0].sum()),
            })
        for topo in topologies[len(k_topos):]:
            k_rows.append(dict(k_rows[0], topology=topo))
        rows.extend(k_rows)

    def row(k, topo):
        return next(r for r in rows if r["k"] == k and r["topology"] == topo)

    # headline: on the paper's own fabric, the clustered configuration
    # carries the lowest total management latency
    hier = {k: row(k, "hier_tree") for k in g["ks"]}
    clustered_wins = all(
        hier[clustered]["total_mgmt_latency"] < hier[k]["total_mgmt_latency"]
        for k in g["ks"] if k != clustered)
    # per-receiver beacon ages are verifiably heterogeneous off-ideal
    skew_hetero = {topo: row(clustered, topo)["bcn_skew_max"] > 0.0
                   for topo in topologies if topo != "ideal"}
    ideal_skew_zero = row(clustered, "ideal")["bcn_skew_max"] == 0.0

    # bitwise anchor: the ideal row reproduces a direct (topology-default)
    # sim.run — the transport subsystem is invisible until opted into
    pd = SimParams(m=m, k=clustered, n_childs=g["n_childs"],
                   max_apps=g["max_apps"], c_s=g["c_s"],
                   queue_cap=g["queue_cap"].get(clustered,
                                                g["default_queue_cap"]))
    wl0 = W.interference(pd, sim_len=g["sim_len"],
                         pair_period=g["pair_periods"][0], seed=g["seeds"][0])
    st0 = sim_run(pd, *wl0, g["sim_len"])
    stI = SW.sweep(pd.shape, knobs,
                   W.interference_batch(pd, seeds=(g["seeds"][0],),
                                        sim_len=g["sim_len"],
                                        pair_period=g["pair_periods"][0]),
                   g["sim_len"], topology="ideal")
    ideal_bitwise = bool(
        np.array_equal(np.asarray(stI["app_done"])[0, 0],
                       np.asarray(st0["app_done"]))
        and int(np.asarray(stI["beacons_tx"])[0, 0])
        == int(st0["beacons_tx"]))

    payload = {
        "grid": grid,
        "rows": rows,
        "clustered_k": clustered,
        "meta": topology_meta(topologies=list(topologies),
                              grid=grid, m=m, ks=list(g["ks"])),
        "paper_claim": "clustered management reduces both the computation "
                       "(vs k=1) and communication (vs k=m) overhead of "
                       "run-time management (Sec 5.4, Table 5)",
        "claim_ideal_bitwise_vs_run": ideal_bitwise,
        "claim_clustered_lowest_total_mgmt_latency": bool(clustered_wins),
        "claim_skew_heterogeneous_nonideal": bool(all(skew_hetero.values())),
        "claim_skew_zero_ideal": bool(ideal_skew_zero),
        "claim_no_drops": all(r["dropped"] == 0 for r in rows),
        "skew_by_topology": skew_hetero,
    }
    save("topology_frontier", payload)
    if verbose:
        csv_row("topology_frontier", t_total * 1e6,
                f"clustered_best={clustered_wins}"
                f"|ideal_bitwise={ideal_bitwise}"
                f"|skew_ok={payload['claim_skew_heterogeneous_nonideal']}")
        for r in rows:
            print(f"  k={r['k']:4d} {r['topology']:>10}: "
                  f"comm={r['comm_latency']:.3g} proc={r['proc_latency']:.3g} "
                  f"total={r['total_mgmt_latency']:.3g} "
                  f"skew_max={r['bcn_skew_max']:g} "
                  f"resp={r['mean_response']:.0f}")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--grid", choices=sorted(GRIDS), default="default")
    args = ap.parse_args()
    run(grid=args.grid)
