"""Topology frontier: the paper's centralized / clustered / distributed
comparison with the management-communication overhead broken out per
interconnect fabric (paper Sec 5.4 + Table 5; DESIGN.md §10).

``baseline_compare`` reproduces the response-time ordering; this
benchmark explains *why* by routing all management messages through the
explicit transport model (``core/transport.py``) and separating

  comm  — transport latency: sum of (delivery - ready) over every
          management message (task-starts, join-exits + forwards,
          per-receiver beacon deliveries),
  proc  — manager latency: GMN queueing + service for fork expansion,
          stage-2 decision batches, and barrier decrements.

The paper's claim decomposes cleanly: the centralized k=1 manager drowns
in ``proc`` (decision serialization) *and* in ``comm`` (one local bus
carries every task-start/join of m PEs); the fully-distributed k=m
configuration pays ``comm`` for the all-to-all beacon/spawn traffic; a
clustered configuration (1 < k < m) minimizes the total on the paper's
own ``hier_tree`` fabric.  Per-receiver beacon skew (``bcn_skew_*``)
is reported per topology — zero under ``ideal`` by construction,
strictly positive under the non-ideal fabrics (the heterogeneity that
feeds the ``staleness_weighted`` policy).

Grid tiers (schema v3, benchmarks/README.md):

  tiny        CI smoke at m=16, every fabric, linear queue.
  paper_tiny  CI proxy for the paper grid at m=64 with the tournament-
              tree queue (``queue_impl="tree"``, core/eventq.py): gates
              the tree-vs-linear bitwise claim and an events/sec floor
              at a scale GitHub runners finish in minutes.
  default     the PR-3 m=64 saturation-regime grid (c_s raised
              uniformly), unchanged for trajectory continuity.
  paper       the true paper scale: m=256, k ∈ {1, 16, 32, 256} across
              ideal/hier_tree/mesh2d.  The m=256/k=256 points on
              non-ideal fabrics are exactly what ROADMAP.md called
              blocked on the O(queue_cap) argmin: every beacon fans out
              into k-1 = 255 BEACON_RX events, so this tier runs on the
              tournament-tree queue and records events/sec and
              marginal cost per grid point next to PR 1's numbers.

Every row reports ``events`` / ``events_per_sec`` / ``wall_s`` (total
for the point, first seed carries the XLA compile) and
``marginal_wall_s`` (mean of the warm per-seed runs — the steady-state
cost of one more grid point, the number PR 1 tracked).

Usage:  PYTHONPATH=src python -m benchmarks.topology_frontier \
            [--grid tiny|paper_tiny|default|paper]
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.core import sweep as SW
from repro.core import workloads as W
from repro.core.sim import SimParams
from repro.core.sim import run as sim_run
from repro.core.transport import TOPOLOGIES

from benchmarks.common import csv_row, save, timed, topology_meta

# PR 1 measured the sweep engine's marginal cost per design-space point
# at 2.4 s (m=256, 4e6 ticks, ideal fabric, linear queue; CHANGES.md).
# The paper grid reports its marginal_wall_s per row beside this anchor.
PR1_MARGINAL_S_PER_POINT = 2.4

# In the m=64 tiers the c_s knob is raised (uniformly across every
# configuration, so the comparison stays fair) to put the centralized
# manager into the paper's saturation regime at reduced scale; the
# `paper` tier runs the true m=256 scale with the paper's own c_s=8.
GRIDS = {
    # CI smoke: all (k x topology) combos in well under two minutes
    "tiny": dict(m=16, ks=(1, 4, 16), n_childs=16, max_apps=64,
                 queue_cap={16: 2048}, default_queue_cap=1024,
                 c_s=256.0, dn_th=4, sim_len=4e5,
                 pair_periods=(33_000.0,), seeds=(0,),
                 queue_impl="linear", topologies=TOPOLOGIES),
    # CI proxy for the paper grid: small Q, m=64, tournament-tree queue
    "paper_tiny": dict(m=64, ks=(1, 8, 64), n_childs=50, max_apps=128,
                       queue_cap={64: 4096}, default_queue_cap=2048,
                       c_s=40.0, dn_th=4, sim_len=4e5,
                       pair_periods=(26_000.0,), seeds=(0, 1),
                       queue_impl="tree",
                       topologies=("ideal", "hier_tree", "mesh2d")),
    "default": dict(m=64, ks=(1, 8, 64), n_childs=50, max_apps=256,
                    queue_cap={64: 8192}, default_queue_cap=4096,
                    c_s=40.0, dn_th=4, sim_len=2e6,
                    pair_periods=(26_000.0,), seeds=(1, 2),
                    queue_impl="linear", topologies=TOPOLOGIES),
    # the true paper scale (Sec 5 / Table 5): m=256 with the calibrated
    # interference stimulus; k=256 is the fully-distributed extreme whose
    # 255-wide beacon fan-out (hundreds of thousands of BEACON_RX
    # events through a 32k-slot queue) is the point the linear argmin
    # could not reach on CPU
    "paper": dict(m=256, ks=(1, 16, 32, 256), n_childs=100, max_apps=64,
                  queue_cap={256: 32768}, default_queue_cap=8192,
                  c_s=8.0, dn_th=4, sim_len=1e6,
                  pair_periods=(14_000.0,), seeds=(1, 2),
                  queue_impl="tree",
                  topologies=("ideal", "hier_tree", "mesh2d")),
}


def _point(p, knobs, topo, combos, sim_len):
    """Run one (k, topology) grid point seed-by-seed so the warm runs are
    individually timed.  Returns (stacked state with (B, S, ...) leaves,
    wall_s, marginal_wall_s)."""
    sts, dts = [], []
    for pp, seed in combos:
        wl = W.interference_batch(p, seeds=(seed,), sim_len=sim_len,
                                  pair_period=pp)
        # np.asarray inside timed(): sweep returns unrealized async jax
        # arrays, so timing must include materialization
        st, dt = timed(lambda: jax.tree.map(
            np.asarray, SW.sweep(p.shape, knobs, wl, sim_len,
                                 policy=SW.SimPolicy(), topology=topo)))
        sts.append(st)
        dts.append(dt)
    st = jax.tree.map(lambda *leaves: np.concatenate(leaves, axis=1), *sts)
    # the first seed's run carries the XLA compile for this static combo;
    # the warm remainder is the marginal cost of one more grid point.  A
    # single-combo grid re-times one warm repeat (results are
    # deterministic and discarded) so marginal/warm fields always mean
    # steady state, never compile
    if len(dts) > 1:
        marginal = float(np.mean(dts[1:]))
    else:
        pp, seed = combos[0]
        wl = W.interference_batch(p, seeds=(seed,), sim_len=sim_len,
                                  pair_period=pp)
        _, marginal = timed(lambda: jax.tree.map(
            np.asarray, SW.sweep(p.shape, knobs, wl, sim_len,
                                 policy=SW.SimPolicy(), topology=topo)))
    return st, float(np.sum(dts)), marginal


def run(verbose: bool = True, grid: str = "default",
        topologies=None) -> dict:
    g = GRIDS[grid]
    topologies = tuple(topologies if topologies is not None
                       else g["topologies"])
    missing = {"ideal", "hier_tree"} - set(topologies)
    if missing:
        raise ValueError(f"the headline claims need the {sorted(missing)} "
                         "fabric(s) in `topologies`")
    m, qi = g["m"], g["queue_impl"]
    clustered_ks = [k for k in g["ks"] if 1 < k < m]
    combos = [(pp, s) for pp in g["pair_periods"] for s in g["seeds"]]
    knobs = SW.knob_batch(dn_th=g["dn_th"], c_s=g["c_s"])
    rows = []
    t_total = 0.0
    events_run = 0                # events from actually-run points only
                                  # (k=1 replicas excluded)
    for k in g["ks"]:
        p = SimParams(m=m, k=k, n_childs=g["n_childs"],
                      max_apps=g["max_apps"], queue_impl=qi,
                      queue_cap=g["queue_cap"].get(k, g["default_queue_cap"]))
        # with a single cluster no inter-GMN traffic exists, so every
        # fabric produces identical results: run once, replicate the row
        k_topos = topologies if k > 1 else topologies[:1]
        k_rows = []
        for topo in k_topos:
            st, wall, marginal = _point(p, knobs, topo, combos, g["sim_len"])
            t_total += wall
            events = int(np.asarray(st["events_processed"]).sum())
            events_run += events
            comm = SW.mgmt_latency(st)[0]             # (S,)
            proc = SW.mgmt_proc(st)[0]
            msgs = SW.mgmt_msgs(st)[0]
            skew_max = np.asarray(st["bcn_skew_max"], np.float64)[0]
            k_rows.append({
                "k": k, "topology": topo, "queue_impl": qi,
                "mean_response": float(np.nanmean(SW.mean_response(st)[0])),
                "beacons_tx": int(SW.beacons(st)[0].sum()),
                "beacons_rx": int(SW.beacons_rx(st)[0].sum()),
                "mgmt_msgs": int(msgs.sum()),
                "comm_latency": float(comm.sum()),
                "proc_latency": float(proc.sum()),
                "total_mgmt_latency": float((comm + proc).sum()),
                "comm_per_msg": float(comm.sum() / max(msgs.sum(), 1)),
                "bcn_skew_max": float(skew_max.max()),
                "dropped": int(np.asarray(st["dropped"])[0].sum()),
                "events": events,
                "events_per_sec": events / max(wall, 1e-9),
                "warm_events_per_sec": events / len(combos)
                / max(marginal, 1e-9),
                "wall_s": wall,
                "marginal_wall_s": marginal,
            })
        for topo in topologies[len(k_topos):]:
            k_rows.append(dict(k_rows[0], topology=topo))
        rows.extend(k_rows)

    def row(k, topo):
        return next(r for r in rows if r["k"] == k and r["topology"] == topo)

    # headline: on the paper's own fabric, a clustered configuration
    # carries lower total management latency than both extremes
    hier = {k: row(k, "hier_tree") for k in g["ks"]}
    clustered = min(clustered_ks,
                    key=lambda k: hier[k]["total_mgmt_latency"])
    extremes = [k for k in g["ks"] if k == 1 or k == m]
    clustered_wins = all(
        hier[clustered]["total_mgmt_latency"] < hier[k]["total_mgmt_latency"]
        for k in extremes)
    # per-receiver beacon ages are verifiably heterogeneous off-ideal
    skew_hetero = {topo: row(clustered, topo)["bcn_skew_max"] > 0.0
                   for topo in topologies if topo != "ideal"}
    ideal_skew_zero = row(clustered, "ideal")["bcn_skew_max"] == 0.0

    # bitwise anchor: the ideal row's configuration reproduces a direct
    # (topology- and queue-default) sim.run — neither the transport
    # subsystem nor the tournament-tree queue is visible until opted into
    pd = SimParams(m=m, k=clustered, n_childs=g["n_childs"],
                   max_apps=g["max_apps"], c_s=g["c_s"], dn_th=g["dn_th"],
                   queue_cap=g["queue_cap"].get(clustered,
                                                g["default_queue_cap"]))
    pp0, seed0 = combos[0]
    wl0 = W.interference(pd, sim_len=g["sim_len"], pair_period=pp0,
                         seed=seed0)
    st0 = sim_run(pd, *wl0, g["sim_len"])
    wl0b = W.interference_batch(pd, seeds=(seed0,), sim_len=g["sim_len"],
                                pair_period=pp0)
    stI = SW.sweep(pd.shape, knobs, wl0b, g["sim_len"], topology="ideal",
                   queue_impl=qi)
    ideal_bitwise = bool(
        np.array_equal(np.asarray(stI["app_done"])[0, 0],
                       np.asarray(st0["app_done"]))
        and int(np.asarray(stI["beacons_tx"])[0, 0])
        == int(st0["beacons_tx"]))

    payload = {
        "grid": grid,
        "rows": rows,
        "clustered_k": clustered,
        "queue_impl": qi,
        "meta": topology_meta(topologies=list(topologies), grid=grid, m=m,
                              ks=list(g["ks"]), queue_impl=qi),
        "paper_claim": "clustered management reduces both the computation "
                       "(vs k=1) and communication (vs k=m) overhead of "
                       "run-time management (Sec 5.4, Table 5)",
        "pr1_reference": {
            "marginal_s_per_point": PR1_MARGINAL_S_PER_POINT,
            "context": "m=256, 4e6 ticks, ideal fabric, linear queue "
                       "(CHANGES.md, PR 1)"},
        "claim_ideal_bitwise_vs_run": ideal_bitwise,
        "claim_clustered_lowest_total_mgmt_latency": bool(clustered_wins),
        "claim_skew_heterogeneous_nonideal": bool(all(skew_hetero.values())),
        "claim_skew_zero_ideal": bool(ideal_skew_zero),
        "claim_no_drops": all(r["dropped"] == 0 for r in rows),
        "skew_by_topology": skew_hetero,
    }

    if qi == "tree":
        # the tree queue's bitwise contract, exercised where it matters:
        # a non-ideal fabric whose k-1 beacon fan-out stresses the bulk
        # push, compared leaf-for-leaf against the linear golden anchor
        stL = SW.sweep(pd.shape, knobs, wl0b, g["sim_len"],
                       topology="hier_tree", queue_impl="linear")
        stT = SW.sweep(pd.shape, knobs, wl0b, g["sim_len"],
                       topology="hier_tree", queue_impl="tree")
        payload["claim_tree_matches_linear_bitwise"] = bool(all(
            np.array_equal(np.asarray(stL[key]), np.asarray(stT[key]))
            for key in ("app_done", "app_arrive", "beacons_tx",
                        "beacons_rx", "events_processed", "dropped")))

    save("topology_frontier", payload)
    if verbose:
        csv_row("topology_frontier", t_total * 1e6,
                f"clustered_best={clustered_wins}"
                f"|ideal_bitwise={ideal_bitwise}"
                f"|skew_ok={payload['claim_skew_heterogeneous_nonideal']}"
                f"|queue={qi}"
                f"|events_per_sec={events_run / max(t_total, 1e-9):,.0f}")
        for r in rows:
            print(f"  k={r['k']:4d} {r['topology']:>10}: "
                  f"comm={r['comm_latency']:.3g} proc={r['proc_latency']:.3g} "
                  f"total={r['total_mgmt_latency']:.3g} "
                  f"skew_max={r['bcn_skew_max']:g} "
                  f"resp={r['mean_response']:.0f} "
                  f"ev/s={r['events_per_sec']:,.0f} "
                  f"marg={r['marginal_wall_s']:.2f}s")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--grid", choices=sorted(GRIDS), default="default")
    args = ap.parse_args()
    run(grid=args.grid)
