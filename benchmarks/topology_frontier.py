"""Topology frontier: the paper's centralized / clustered / distributed
comparison with the management-communication overhead broken out per
interconnect fabric (paper Sec 5.4 + Table 5; DESIGN.md §10).

``baseline_compare`` reproduces the response-time ordering; this
benchmark explains *why* by routing all management messages through the
explicit transport model (``core/transport.py``) and separating

  comm  — transport latency: sum of (delivery - ready) over every
          management message (task-starts, join-exits + forwards,
          per-receiver beacon deliveries),
  proc  — manager latency: GMN queueing + service for fork expansion,
          stage-2 decision batches, and barrier decrements.

The paper's claim decomposes cleanly: the centralized k=1 manager drowns
in ``proc`` (decision serialization) *and* in ``comm`` (one local bus
carries every task-start/join of m PEs); the fully-distributed k=m
configuration pays ``comm`` for the all-to-all beacon/spawn traffic; a
clustered configuration (1 < k < m) minimizes the total on the paper's
own ``hier_tree`` fabric.  Per-receiver beacon skew (``bcn_skew_*``)
is reported per topology — zero under ``ideal`` by construction,
strictly positive under the non-ideal fabrics.

The whole (k x topology x seed) grid is TWO declarative experiments
(core/experiment.py): one spanning every k > 1 across every fabric, and
a single-fabric spec for k=1 (with one cluster no inter-GMN traffic
exists, so every fabric is identical — the other fabrics' rows are
replicas).  The planner compiles one XLA program per (shape incl.
queue_cap/queue_impl, topology) group; seq dispatch times every lane
individually, so the per-seed warm/marginal cost fields survive the
port.  The tree-vs-linear bitwise gate rides the declarative
``queue_impls`` axis of a third tiny spec.

Grid tiers (schema v4, benchmarks/README.md):

  tiny        CI smoke at m=16, every fabric, linear queue.
  paper_tiny  CI proxy for the paper grid at m=64 with the tournament-
              tree queue (``queue_impl="tree"``, core/eventq.py).
  default     the PR-3 m=64 saturation-regime grid (c_s raised
              uniformly), unchanged for trajectory continuity.
  paper       the true paper scale: m=256, k ∈ {1, 16, 32, 256} across
              ideal/hier_tree/mesh2d on the tournament-tree queue.

Every row reports ``events`` / ``events_per_sec`` / ``wall_s`` (total
for the point, first seed carries the XLA compile) and
``marginal_wall_s`` (mean of the warm per-seed runs — the steady-state
cost of one more grid point, the number PR 1 tracked).

Usage:  PYTHONPATH=src python -m benchmarks.topology_frontier \
            [--grid tiny|paper_tiny|default|paper]
"""
from __future__ import annotations

import argparse
import dataclasses

import numpy as np

from repro.core import workloads as W
from repro.core.experiment import ExperimentSpec, WorkloadSpec
from repro.core.sim import SimParams
from repro.core.sim import run as sim_run
from repro.core.transport import TOPOLOGIES

from benchmarks.common import csv_row, save, timed, topology_meta

# PR 1 measured the sweep engine's marginal cost per design-space point
# at 2.4 s (m=256, 4e6 ticks, ideal fabric, linear queue; CHANGES.md).
# The paper grid reports its marginal_wall_s per row beside this anchor.
PR1_MARGINAL_S_PER_POINT = 2.4

# In the m=64 tiers the c_s knob is raised (uniformly across every
# configuration, so the comparison stays fair) to put the centralized
# manager into the paper's saturation regime at reduced scale; the
# `paper` tier runs the true m=256 scale with the paper's own c_s=8.
GRIDS = {
    # CI smoke: all (k x topology) combos in well under two minutes
    "tiny": dict(m=16, ks=(1, 4, 16), n_childs=16, max_apps=64,
                 queue_cap={16: 2048}, default_queue_cap=1024,
                 c_s=256.0, dn_th=4, sim_len=4e5,
                 pair_periods=(33_000.0,), seeds=(0,),
                 queue_impl="linear", topologies=TOPOLOGIES),
    # CI proxy for the paper grid: small Q, m=64, tournament-tree queue
    "paper_tiny": dict(m=64, ks=(1, 8, 64), n_childs=50, max_apps=128,
                       queue_cap={64: 4096}, default_queue_cap=2048,
                       c_s=40.0, dn_th=4, sim_len=4e5,
                       pair_periods=(26_000.0,), seeds=(0, 1),
                       queue_impl="tree",
                       topologies=("ideal", "hier_tree", "mesh2d")),
    "default": dict(m=64, ks=(1, 8, 64), n_childs=50, max_apps=256,
                    queue_cap={64: 8192}, default_queue_cap=4096,
                    c_s=40.0, dn_th=4, sim_len=2e6,
                    pair_periods=(26_000.0,), seeds=(1, 2),
                    queue_impl="linear", topologies=TOPOLOGIES),
    # the true paper scale (Sec 5 / Table 5): m=256 with the calibrated
    # interference stimulus; k=256 is the fully-distributed extreme whose
    # 255-wide beacon fan-out (hundreds of thousands of BEACON_RX
    # events through a 32k-slot queue) needs the tournament-tree queue
    "paper": dict(m=256, ks=(1, 16, 32, 256), n_childs=100, max_apps=64,
                  queue_cap={256: 32768}, default_queue_cap=8192,
                  c_s=8.0, dn_th=4, sim_len=1e6,
                  pair_periods=(14_000.0,), seeds=(1, 2),
                  queue_impl="tree",
                  topologies=("ideal", "hier_tree", "mesh2d")),
}


def _shape_for(g, k):
    return SimParams(m=g["m"], k=k, n_childs=g["n_childs"],
                     max_apps=g["max_apps"], queue_impl=g["queue_impl"],
                     queue_cap=g["queue_cap"].get(k, g["default_queue_cap"])
                     ).shape


def run(verbose: bool = True, grid: str = "default",
        topologies=None) -> dict:
    g = GRIDS[grid]
    topologies = tuple(topologies if topologies is not None
                       else g["topologies"])
    missing = {"ideal", "hier_tree"} - set(topologies)
    if missing:
        raise ValueError(f"the headline claims need the {sorted(missing)} "
                         "fabric(s) in `topologies`")
    m, qi = g["m"], g["queue_impl"]
    clustered_ks = [k for k in g["ks"] if 1 < k < m]
    n_lanes = len(g["pair_periods"]) * len(g["seeds"])
    workload = WorkloadSpec.make("interference", seeds=g["seeds"],
                                 pair_periods=tuple(g["pair_periods"]))
    knobs = {"dn_th": g["dn_th"], "c_s": g["c_s"]}

    # with a single cluster no inter-GMN traffic exists, so every fabric
    # produces identical results: run k=1 on the first fabric only and
    # replicate its row across the rest
    specs = []
    if 1 in g["ks"]:
        specs.append(ExperimentSpec(shapes=(_shape_for(g, 1),),
                                    topologies=topologies[:1],
                                    knobs=knobs, workloads=(workload,),
                                    sim_len=g["sim_len"], mode="seq"))
    ks_multi = tuple(k for k in g["ks"] if k > 1)
    if ks_multi:
        specs.append(ExperimentSpec(
            shapes=tuple(_shape_for(g, k) for k in ks_multi),
            topologies=topologies, knobs=knobs, workloads=(workload,),
            sim_len=g["sim_len"], mode="seq"))

    frames, t_total = [], 0.0
    for spec in specs:
        frame, dt = timed(spec.run)
        frames.append(frame)
        t_total += dt
    # single-lane grids: the lone lane of each group carried the XLA
    # compile, so re-run the whole (now warm) spec once to measure the
    # steady-state marginal cost.  Results are deterministic and
    # discarded, and the re-run stays OFF t_total — the historical
    # series times only the actually-reported points
    warm_lane = {}
    if n_lanes == 1:
        for spec in specs:
            wf = spec.run()
            for gr in wf.groups:
                key = (gr.combo.shape.k, gr.combo.topology.kind)
                warm_lane[key] = list(gr.lane_wall_s)

    rows = []
    events_run = 0                # events from actually-run points only
                                  # (k=1 replicas excluded)
    for frame in frames:
        for gr in frame.groups:
            k, topo = gr.combo.shape.k, gr.combo.topology.kind
            st = gr.state
            events = int(np.asarray(st["events_processed"]).sum())
            events_run += events
            comm = np.asarray(st["mgmt_latency"], np.float64)[0]   # (S,)
            proc = np.asarray(st["mgmt_proc"], np.float64)[0]
            msgs = np.asarray(st["mgmt_msgs"], np.int64)[0]
            wall = float(gr.wall_s)
            lane_walls = list(gr.lane_wall_s)
            warm = warm_lane.get((k, topo), lane_walls[1:])
            marginal = float(np.mean(warm))
            rows.append({
                "k": k, "topology": topo, "queue_impl": qi,
                "mean_response": float(np.nanmean(
                    frame.mean_response(k=k, topology=topo))),
                "beacons_tx": int(np.asarray(st["beacons_tx"]).sum()),
                "beacons_rx": int(np.asarray(st["beacons_rx"]).sum()),
                "mgmt_msgs": int(msgs.sum()),
                "comm_latency": float(comm.sum()),
                "proc_latency": float(proc.sum()),
                "total_mgmt_latency": float((comm + proc).sum()),
                "comm_per_msg": float(comm.sum() / max(msgs.sum(), 1)),
                "bcn_skew_max": float(
                    np.asarray(st["bcn_skew_max"], np.float64).max()),
                "dropped": int(np.asarray(st["dropped"]).sum()),
                "events": events,
                "events_per_sec": events / max(wall, 1e-9),
                "warm_events_per_sec": events / n_lanes
                / max(marginal, 1e-9),
                "wall_s": wall,
                "marginal_wall_s": marginal,
            })
    # replicate the fabric-invariant k=1 row across the unrun fabrics,
    # keeping the historical row order (all k=1 rows first)
    if 1 in g["ks"]:
        k1 = next(r for r in rows if r["k"] == 1)
        at = rows.index(k1) + 1
        rows[at:at] = [dict(k1, topology=topo) for topo in topologies[1:]]

    def row(k, topo):
        return next(r for r in rows if r["k"] == k and r["topology"] == topo)

    # headline: on the paper's own fabric, a clustered configuration
    # carries lower total management latency than both extremes
    hier = {k: row(k, "hier_tree") for k in g["ks"]}
    clustered = min(clustered_ks,
                    key=lambda k: hier[k]["total_mgmt_latency"])
    extremes = [k for k in g["ks"] if k == 1 or k == m]
    clustered_wins = all(
        hier[clustered]["total_mgmt_latency"] < hier[k]["total_mgmt_latency"]
        for k in extremes)
    # per-receiver beacon ages are verifiably heterogeneous off-ideal
    skew_hetero = {topo: row(clustered, topo)["bcn_skew_max"] > 0.0
                   for topo in topologies if topo != "ideal"}
    ideal_skew_zero = row(clustered, "ideal")["bcn_skew_max"] == 0.0

    # bitwise anchor: the ideal row's first lane reproduces a direct
    # (topology- and queue-default) sim.run — neither the transport
    # subsystem nor the tournament-tree queue is visible until opted into
    pd = SimParams(m=m, k=clustered, n_childs=g["n_childs"],
                   max_apps=g["max_apps"], c_s=g["c_s"], dn_th=g["dn_th"],
                   queue_cap=g["queue_cap"].get(clustered,
                                                g["default_queue_cap"]))
    pp0, seed0 = g["pair_periods"][0], g["seeds"][0]
    wl0 = W.interference(pd, sim_len=g["sim_len"], pair_period=pp0,
                         seed=seed0)
    st0 = sim_run(pd, *wl0, g["sim_len"])
    mframe = frames[-1]
    stI = mframe.state(k=clustered, topology="ideal")
    ideal_bitwise = bool(
        np.array_equal(np.asarray(stI["app_done"])[0, 0],
                       np.asarray(st0["app_done"]))
        and int(np.asarray(stI["beacons_tx"])[0, 0])
        == int(st0["beacons_tx"]))

    n_compiles = sum(f.compiles for f in frames)
    expected = sum(f.expected_programs for f in frames)
    payload = {
        "grid": grid,
        "rows": rows,
        "clustered_k": clustered,
        "queue_impl": qi,
        "meta": topology_meta(topologies=list(topologies), grid=grid, m=m,
                              ks=list(g["ks"]), queue_impl=qi),
        "paper_claim": "clustered management reduces both the computation "
                       "(vs k=1) and communication (vs k=m) overhead of "
                       "run-time management (Sec 5.4, Table 5)",
        "pr1_reference": {
            "marginal_s_per_point": PR1_MARGINAL_S_PER_POINT,
            "context": "m=256, 4e6 ticks, ideal fabric, linear queue "
                       "(CHANGES.md, PR 1)"},
        "n_compiles": n_compiles,
        "claim_one_program_per_group": n_compiles <= expected,
        "claim_ideal_bitwise_vs_run": ideal_bitwise,
        "claim_clustered_lowest_total_mgmt_latency": bool(clustered_wins),
        "claim_skew_heterogeneous_nonideal": bool(all(skew_hetero.values())),
        "claim_skew_zero_ideal": bool(ideal_skew_zero),
        "claim_no_drops": all(r["dropped"] == 0 for r in rows),
        "skew_by_topology": skew_hetero,
    }

    if qi == "tree":
        # the tree queue's bitwise contract, exercised where it matters —
        # a non-ideal fabric whose k-1 beacon fan-out stresses the bulk
        # push — through the declarative queue_impls axis: one spec, two
        # static event-queue structures, leaf-for-leaf equality
        qspec = ExperimentSpec(
            shapes=(dataclasses.replace(_shape_for(g, clustered),
                                        queue_impl="linear"),),
            queue_impls=("linear", "tree"), topologies=("hier_tree",),
            knobs=knobs,
            workloads=(WorkloadSpec.make("interference", seeds=(seed0,),
                                         pair_periods=(pp0,)),),
            sim_len=g["sim_len"], mode="seq")
        qframe = qspec.run()
        stL = qframe.state(queue_impl="linear")
        stT = qframe.state(queue_impl="tree")
        payload["claim_tree_matches_linear_bitwise"] = bool(all(
            np.array_equal(np.asarray(stL[key]), np.asarray(stT[key]))
            for key in ("app_done", "app_arrive", "beacons_tx",
                        "beacons_rx", "events_processed", "dropped")))

    save("topology_frontier", payload,
         spec=[s.to_dict() for s in specs])
    if verbose:
        csv_row("topology_frontier", t_total * 1e6,
                f"clustered_best={clustered_wins}"
                f"|ideal_bitwise={ideal_bitwise}"
                f"|skew_ok={payload['claim_skew_heterogeneous_nonideal']}"
                f"|queue={qi}"
                f"|events_per_sec={events_run / max(t_total, 1e-9):,.0f}")
        for r in rows:
            print(f"  k={r['k']:4d} {r['topology']:>10}: "
                  f"comm={r['comm_latency']:.3g} proc={r['proc_latency']:.3g} "
                  f"total={r['total_mgmt_latency']:.3g} "
                  f"skew_max={r['bcn_skew_max']:g} "
                  f"resp={r['mean_response']:.0f} "
                  f"ev/s={r['events_per_sec']:,.0f} "
                  f"marg={r['marginal_wall_s']:.2f}s")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--grid", choices=sorted(GRIDS), default="default")
    args = ap.parse_args()
    run(grid=args.grid)
