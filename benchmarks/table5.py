"""Table 5: speedup comparison for n=100 tasks on m=256 PEs.

  k=1   centralized (Nexus++-like)   paper: 28.1
  k=8   this work                    paper: 73.5
  k=16  this work                    paper: 78.7
  k=256 fully distributed (Isonet)   paper: 44.3

Runs as ONE declarative experiment (core/experiment.py): k is the
static shape axis, the seeds the traced lane axis — one XLA program
per k."""
from __future__ import annotations

import numpy as np

from repro.core.experiment import ExperimentSpec, WorkloadSpec
from repro.core.sim import SimParams

from benchmarks.common import csv_row, save, timed

PAPER = {1: 28.1, 8: 73.5, 16: 78.7, 256: 44.3}


def run(verbose: bool = True, sim_len: float = 4e6, seeds=(1, 2, 3)) -> dict:
    spec = ExperimentSpec(
        base=SimParams(m=256, n_childs=100, max_apps=512, queue_cap=2048),
        shapes=tuple(PAPER),
        knobs={"dn_th": 4},
        workloads=(WorkloadSpec("interference", seeds=seeds),),
        sim_len=sim_len)
    frame, t_total = timed(spec.run)

    rows = {}
    for k in PAPER:
        vals = frame.speedup(k=k)                     # (S,) over seeds
        rows[str(k)] = {"speedup": float(np.mean(vals)),
                        "std": float(np.std(vals)),
                        "paper": PAPER[k]}
    ours_ratio = rows["16"]["speedup"] / rows["1"]["speedup"]
    paper_ratio = PAPER[16] / PAPER[1]
    ordering_ok = (rows["16"]["speedup"] > rows["256"]["speedup"]
                   > rows["1"]["speedup"]) or \
                  (rows["16"]["speedup"] > rows["1"]["speedup"]
                   and rows["16"]["speedup"] > rows["256"]["speedup"])
    payload = {
        "rows": rows,
        "ratio_k16_over_k1": {"ours": float(ours_ratio),
                              "paper": float(paper_ratio)},
        "ordering_clustered_best": ordering_ok,
        "note": "absolute speedups depend on the unpublished stimulus "
                "period (calibrated, see workloads.interference); the "
                "paper's claim is the ORDERING and the ~2.8x ratio",
    }
    save("table5", payload, spec=spec)
    if verbose:
        csv_row("table5_comparison", t_total * 1e6,
                f"k16/k1={ours_ratio:.2f}(paper {paper_ratio:.2f})"
                f"|ordering_ok={ordering_ok}")
    return payload


if __name__ == "__main__":
    run()
