"""Policy-space Pareto frontier: beacons transmitted vs mean response time.

Paper Fig 3 trades synchronization traffic against decision quality along
a single axis (the threshold dn_th of the one hard-coded strategy).  This
benchmark generalizes that trade-off to the full pluggable policy space
(core/policies.py) *and* the interconnect fabric (core/transport.py): it
sweeps

    mapping policy x beacon policy x topology x (dn_th, T_b)
                   x scenario (interference / bursty / hotspot) x seed

declaratively (core/experiment.py): one ExperimentSpec per beacon
policy — the beacon policy fixes which knob axes are alive (T_b is dead
under ``threshold``, dn_th under ``periodic``; sweeping a dead knob
would just duplicate grid points) — each carrying the full mapping x
topology static axes and all three scenario WorkloadSpecs.  The planner
compiles one XLA program per (mapping, beacon, topology) combination;
knobs, seeds and scenarios ride the traced axes for free.

The ``dominant_pairs`` key records which (mapping, beacon, topology)
triples survive on each scenario's frontier; the default ``min_search``
+ ``threshold`` pair on the ``ideal`` fabric is additionally checked
bitwise against a direct ``sim.run`` call, and the legacy ``frontier``
key still holds exactly the interference/ideal frontier so the BENCH
trajectory series stays comparable.

Usage:  PYTHONPATH=src python -m benchmarks.policy_frontier [--grid tiny]
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core import workloads as W
from repro.core.experiment import ExperimentSpec, WorkloadSpec
from repro.core.metrics import mean_response
from repro.core.policies import BEACON_POLICIES, MAPPING_POLICIES
from repro.core.sim import SimParams, run as sim_run

from benchmarks.common import csv_row, save, timed, topology_meta

# Pair periods / arrival rates keep the offered load below 1
# (workloads.offered_load): a saturated system backlogs until the event
# queue drops work, which voids the response-time signal —
# claim_all_combos_completed gates this.
GRIDS = {
    # CI smoke: every policy x topology combination end-to-end fast
    "tiny": dict(m=16, k=4, n_childs=16, max_apps=32, queue_cap=512,
                 sim_len=4e5, thresholds=(2, 8), periods=(500.0, 4000.0),
                 pair_periods=(36_000.0,), seeds=(0,),
                 scenario_seeds=(0,),
                 topologies=("ideal", "hier_tree"),
                 bursty=dict(iat_on=12_000.0, iat_off=90_000.0),
                 hotspot=dict(mean_iat=30_000.0, hot_frac=0.6)),
    "default": dict(m=64, k=8, n_childs=50, max_apps=256, queue_cap=2048,
                    sim_len=1e6, thresholds=(1, 4, 16),
                    periods=(500.0, 2000.0, 8000.0),
                    pair_periods=(28_000.0, 48_000.0), seeds=(0, 1),
                    scenario_seeds=(0,),
                    topologies=("ideal", "hier_tree"),
                    bursty=dict(iat_on=8_000.0, iat_off=80_000.0),
                    hotspot=dict(mean_iat=24_000.0, hot_frac=0.6)),
}

SCENARIOS = ("interference", "bursty", "hotspot")


def _knob_axes(beacon: str, thresholds, periods) -> dict:
    """Per-policy knob grid: sweep only the parameters the policy reads."""
    if beacon == "threshold":
        return {"dn_th": thresholds}
    if beacon == "periodic":
        return {"T_b": periods}
    return {"dn_th": thresholds, "T_b": periods}


def _scenario_specs(g) -> tuple:
    """The scenario axis as declarative WorkloadSpecs (one spec, three
    lanes of provenance-carrying workload generators)."""
    ss = g["scenario_seeds"]
    return (
        WorkloadSpec.make("interference", seeds=g["seeds"],
                          pair_periods=tuple(g["pair_periods"])),
        WorkloadSpec.make("bursty", seeds=ss, **g["bursty"]),
        WorkloadSpec.make("hotspot", seeds=ss, **g["hotspot"]),
    )


def _pareto_mask(xs, ys):
    """Nondominated points when minimizing both axes."""
    n = len(xs)
    mask = []
    for i in range(n):
        dom = any(xs[j] <= xs[i] and ys[j] <= ys[i]
                  and (xs[j] < xs[i] or ys[j] < ys[i]) for j in range(n))
        mask.append(not dom)
    return mask


def run(verbose: bool = True, grid: str = "default",
        mappings=MAPPING_POLICIES, beacons=BEACON_POLICIES) -> dict:
    g = GRIDS[grid]
    p = SimParams(m=g["m"], k=g["k"], n_childs=g["n_childs"],
                  max_apps=g["max_apps"], queue_cap=g["queue_cap"])
    sim_len = g["sim_len"]
    pair_periods, seeds = g["pair_periods"], g["seeds"]
    topologies = g["topologies"]
    scenarios = _scenario_specs(g)

    # one spec per beacon policy (its knob grid), each spanning the full
    # mapping x topology x scenario space
    specs, frames = {}, {}
    t_total = 0.0
    for beacon in beacons:
        spec = ExperimentSpec(
            base=p,
            policies=tuple((m, beacon) for m in mappings),
            topologies=tuple(topologies),
            knobs=_knob_axes(beacon, g["thresholds"], g["periods"]),
            workloads=scenarios,
            sim_len=sim_len)
        frame, dt = timed(spec.run)
        t_total += dt
        specs[beacon], frames[beacon] = spec, frame

    # flatten to the historical row schema, in the historical order
    # (mapping outermost, then beacon, then topology, then scenario)
    rows = []
    frame_rows = {b: frames[b].rows() for b in beacons}
    for mapping in mappings:
        for beacon in beacons:
            for r in frame_rows[beacon]:
                if r["mapping"] != mapping:
                    continue
                mr = r["mean_response"]
                rows.append({
                    "mapping": mapping, "beacon": beacon,
                    "topology": r["topology"], "scenario": r["workload"],
                    "dn_th": int(r["dn_th"]), "T_b": float(r["T_b"]),
                    "pair_period": r["pair_period"], "seed": r["seed"],
                    "beacons_tx": int(r["beacons_tx"]),
                    "mean_response": float("nan") if mr is None else mr,
                    "dropped": int(r["dropped"]),
                })

    # Bitwise anchor: the default pair on the default fabric reproduces a
    # direct sim.run call
    pd = SimParams(m=g["m"], k=g["k"], n_childs=g["n_childs"],
                   max_apps=g["max_apps"], queue_cap=g["queue_cap"],
                   dn_th=int(g["thresholds"][0]))
    wl0 = W.interference(pd, sim_len=sim_len,
                         pair_period=pair_periods[0], seed=seeds[0])
    st0 = sim_run(pd, *wl0, sim_len)
    anchor = next(r for r in rows
                  if r["mapping"] == "min_search"
                  and r["beacon"] == "threshold"
                  and r["topology"] == "ideal"
                  and r["scenario"] == "interference"
                  and r["dn_th"] == int(g["thresholds"][0])
                  and r["pair_period"] == float(pair_periods[0])
                  and r["seed"] == int(seeds[0]))
    # same mean_response code path as the frame rows, so float equality
    # really is a bitwise check of the underlying app_done/app_arrive
    mr0 = float(mean_response(
        {"app_done": np.asarray(st0["app_done"])[None, None],
         "app_arrive": np.asarray(st0["app_arrive"])[None, None]})[0, 0])
    default_bitwise = (anchor["beacons_tx"] == int(st0["beacons_tx"])
                       and anchor["mean_response"] == mr0)

    # Pareto frontiers over (beacons_tx, mean_response), minimizing both,
    # per scenario across the (policy x topology) space; lanes with no
    # completed application carry no response-time signal
    for r in rows:
        r["pareto"] = False
    frontier_by_scenario = {}
    dominant_pairs = {}
    for scenario in SCENARIOS:
        cand = [r for r in rows if r["scenario"] == scenario
                and np.isfinite(r["mean_response"])]
        mask = _pareto_mask([r["beacons_tx"] for r in cand],
                            [r["mean_response"] for r in cand])
        for r, nd in zip(cand, mask):
            r["pareto"] = r["pareto"] or bool(nd)
        front = sorted((r for r, nd in zip(cand, mask) if nd),
                       key=lambda r: r["beacons_tx"])
        frontier_by_scenario[scenario] = front
        dominant_pairs[scenario] = sorted(
            {(r["mapping"], r["beacon"], r["topology"]) for r in front})

    # legacy frontier: the interference scenario on the ideal fabric only
    # (the exact pre-topology grid), so the BENCH series stays comparable
    legacy = [r for r in rows if r["scenario"] == "interference"
              and r["topology"] == "ideal"
              and np.isfinite(r["mean_response"])]
    lmask = _pareto_mask([r["beacons_tx"] for r in legacy],
                         [r["mean_response"] for r in legacy])
    frontier = sorted((r for r, nd in zip(legacy, lmask) if nd),
                      key=lambda r: r["beacons_tx"])
    frontier_pairs = {(r["mapping"], r["beacon"]) for r in frontier}

    n_compiles = sum(f.compiles for f in frames.values())
    expected = sum(f.expected_programs for f in frames.values())
    payload = {
        "grid": grid,
        "rows": rows,
        "frontier": frontier,
        "frontier_by_scenario": frontier_by_scenario,
        "dominant_pairs": {s: [list(t) for t in v]
                           for s, v in dominant_pairs.items()},
        "scenarios": list(SCENARIOS),
        "meta": topology_meta(topologies=list(topologies), grid=grid),
        "n_policy_combos": len(mappings) * len(beacons),
        "n_points": len(rows),
        "n_compiles": n_compiles,
        "claim_default_bitwise_vs_run": bool(default_bitwise),
        "claim_frontier_nonempty": len(frontier) > 0,
        "claim_all_combos_completed": all(
            np.isfinite(r["mean_response"]) and r["dropped"] == 0
            for r in rows),
        # the trade-off space is real: no single policy pair dominates
        "claim_frontier_spans_policies": len(frontier_pairs) >= 2,
        "claim_all_scenario_frontiers_nonempty": all(
            len(v) > 0 for v in frontier_by_scenario.values()),
        # compile-aware planner accounting: one XLA program per
        # (mapping, beacon, topology) group
        "claim_one_program_per_group": n_compiles <= expected,
    }
    save("policy_frontier", payload,
         spec={b: s.to_dict() for b, s in specs.items()})
    if verbose:
        csv_row("policy_frontier", t_total * 1e6,
                f"combos={payload['n_policy_combos']}"
                f"|points={len(rows)}|frontier={len(frontier)}"
                f"|default_bitwise={default_bitwise}")
        for scenario in SCENARIOS:
            pairs = ", ".join("+".join(t) for t in dominant_pairs[scenario])
            print(f"  {scenario} frontier pairs: {pairs}")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--grid", choices=sorted(GRIDS), default="default")
    args = ap.parse_args()
    run(grid=args.grid)
