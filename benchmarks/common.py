"""Shared helpers for the paper-reproduction benchmarks.

Output contract (consumed by the BENCH_*.json trajectory tracking — see
benchmarks/README.md for the full schema): each benchmark module's
``run()`` writes ``results/<name>.json`` via :func:`save` and prints one
``name,us_per_call,derived`` CSV row via :func:`csv_row`.  The JSON
payload is a flat dict whose keys are stable across PRs: measured data
under ``curves``/``rows``, paper reference values under ``paper_claim``,
and one boolean per headline claim prefixed ``claim_`` (plus
free-standing booleans like ``ordering_clustered_best``).  Trajectory
tooling snapshots ``results/<name>.json`` into ``BENCH_<name>.json`` per
PR and diffs numeric leaves, so renaming or re-nesting keys breaks the
time series — add new keys instead of mutating existing ones."""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core.transport import TOPOLOGIES

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")

# JSON schema version of the benchmark payloads.  v2 added the "meta"
# block (topology_meta below): results/*.json are self-describing about
# which interconnect fabric produced each number.  v3 added the
# throughput/cost fields that benchmarks riding the event-queue axis
# report per row — `events`, `events_per_sec`, `wall_s`,
# `marginal_wall_s`, `queue_impl` — plus the `paper` grid tier of
# benchmarks/topology_frontier.py.  v4 embeds the serialized
# ExperimentSpec that produced the numbers under a top-level "spec" key
# (core/experiment.py; null for benchmarks that don't ride the
# experiment engine) — every payload carries its full design-space
# provenance (see benchmarks/README.md).  v5 adds the fault-injection
# axis (DESIGN.md §13): specs may carry a "faults" list (serialized
# FaultSpecs; SPEC_VERSION 2), rows the availability columns
# `fault` / `msgs_lost` / `reroutes` / `downtime`, and fault-aware
# benchmarks a top-level `determinism_digest` (sha256 over the
# deterministic row fields, wall-clock excluded) that CI compares
# across two runs of the same fault seed.
SCHEMA_VERSION = 5


def topology_meta(topologies=("ideal",), **extra) -> dict:
    """Standard self-description block for benchmark payloads: which
    fabric models the numbers were produced under ("ideal" is the
    pre-transport behavior, bitwise), plus the full topology vocabulary
    so downstream tooling can interpret per-topology keys without
    importing the simulator."""
    return {
        "schema_version": SCHEMA_VERSION,
        "topologies": list(topologies),
        "topology_vocabulary": list(TOPOLOGIES),
        "topology_default": "ideal",
        **extra,
    }


def determinism_digest(rows, exclude=("wall_s", "lane_wall_s",
                                      "events_per_sec", "marginal_wall_s",
                                      "us_per_call")) -> str:
    """sha256 over the deterministic fields of a row list (schema v5).

    Wall-clock columns are excluded; everything else — coordinates,
    knobs, simulation metrics, fault counters — must be bit-identical
    when a benchmark re-runs with the same seeds, which is exactly what
    the CI fault-smoke job asserts by diffing two digests."""
    import hashlib
    clean = [{k: v for k, v in sorted(r.items()) if k not in exclude}
             for r in rows]
    blob = json.dumps(clean, sort_keys=True, default=float)
    return hashlib.sha256(blob.encode()).hexdigest()


def save(name: str, payload: dict, spec=None):
    """Write ``results/<name>.json``.  ``spec`` is the ExperimentSpec (or
    its ``to_dict()``) that produced the payload — embedded verbatim as
    schema-v4 provenance; None marks a benchmark that doesn't ride the
    experiment engine."""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    payload.setdefault("meta", topology_meta())
    if hasattr(spec, "to_dict"):
        spec = spec.to_dict()        # a benchmark may also pass a dict or
                                     # list of already-serialized specs
    payload.setdefault("spec", spec)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def timed(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    return out, time.time() - t0


def csv_row(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
