"""Shared helpers for the paper-reproduction benchmarks."""
from __future__ import annotations

import json
import os
import time

import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def save(name: str, payload: dict):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def timed(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    return out, time.time() - t0


def csv_row(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
